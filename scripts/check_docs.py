#!/usr/bin/env python
"""Docs drift checker (fast tier; see tests/test_docs.py).

Documentation rots in three ways this script makes impossible:

1. **Dead examples** — every fenced ```python block in README.md and
   docs/*.md is executed (blocks share one namespace per file, top to
   bottom, like a fresh REPL session).  A snippet that stops running
   fails the fast tier.
2. **Stale registry names** — the kernel names documented between the
   ``<!-- kernels:begin/end -->`` markers in docs/engine.md AND in
   README.md must equal ``repro.engine.available_kernels()`` exactly;
   a kernel added to (or renamed in) the registry without touching
   both documents fails the fast tier.
3. **Stale numbers** — the packed-vs-unpacked throughput table in
   README.md must be byte-identical to the one this script regenerates
   from BENCH_kernels.json (``python scripts/check_docs.py --table``
   prints it for pasting after a bench re-run).
4. **Stale index** — docs/README.md is the reading-order map of the
   docs/ pages; it must link every docs/*.md page in DOC_FILES and
   nothing else, so adding a page without indexing it (or indexing a
   deleted page) fails the fast tier.

Exit code 0 = docs match the code.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

DOC_FILES = ("README.md", "docs/README.md", "docs/engine.md",
             "docs/simulator.md", "docs/grid.md", "docs/serving.md",
             "docs/observability.md", "docs/analysis.md",
             "docs/security.md", "benchmarks/README.md")
FENCE_RE = re.compile(r"^```(\w*)[^\n]*\n(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)
KERNEL_MARK_RE = re.compile(
    r"<!--\s*kernels:begin\s*-->(.*?)<!--\s*kernels:end\s*-->", re.DOTALL)


def fenced_blocks(text: str) -> list[tuple[str, str]]:
    """[(language, body)] for every fenced code block, in order."""
    return [(m.group(1), m.group(2)) for m in FENCE_RE.finditer(text)]


def kernel_table(json_path: pathlib.Path) -> list[str]:
    """The README throughput table, regenerated from BENCH_kernels.json."""
    bench = json.loads(json_path.read_text())
    lanes = sorted({v["L"] for k, v in bench.items()
                    if k.startswith("gf_encode_") and v.get("s") == 8
                    and v.get("K") == 10})
    lines = [
        "| L (symbols) | `jnp` Msym/s | `jnp_clmul` Msym/s "
        "| `jnp_packed` Msym/s | `jnp_packed_seeded` Msym/s "
        "| packed / unpacked | seeded / materialized |",
        "|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for L in lanes:
        cells = [f"{L:,}"]
        for kern in ("jnp", "jnp_clmul", "jnp_packed",
                     "jnp_packed_seeded"):
            r = bench[f"gf_encode_{kern}_s8_K10_L{L}"]
            cells.append(f"{r['symbols_per_s'] / 1e6:.0f}")
        speedup = bench[f"packed_vs_unpacked_speedup_L{L}"]["x"]
        cells.append(f"{speedup:.2f}x")
        ratio = bench[f"seeded_vs_materialized_L{L}"]["x"]
        cells.append(f"{ratio:.2f}x")
        lines.append("| " + " | ".join(cells) + " |")
    return lines


def check_python_blocks(path: pathlib.Path) -> list[str]:
    """Execute the file's ```python blocks; return failure messages."""
    errors = []
    ns: dict = {"__name__": f"docs_exec_{path.stem}"}
    for i, (lang, body) in enumerate(fenced_blocks(path.read_text())):
        if lang != "python":
            continue
        try:
            exec(compile(body, f"{path}#block{i}", "exec"), ns)
        except Exception as e:
            errors.append(f"{path}: python block {i} raised "
                          f"{type(e).__name__}: {e}")
    return errors


def check_kernel_names(path: pathlib.Path) -> list[str]:
    """Registry names documented in `path` == the live registry."""
    from repro.engine import available_kernels
    m = KERNEL_MARK_RE.search(path.read_text())
    if not m:
        return [f"{path}: missing <!-- kernels:begin/end --> markers"]
    documented = set(re.findall(r"`([\w]+)`", m.group(1)))
    live = set(available_kernels())
    if documented != live:
        return [f"{path}: documented kernels {sorted(documented)} != "
                f"registry {sorted(live)}"]
    return []


def check_docs_index(index: pathlib.Path) -> list[str]:
    """docs/README.md links exactly the docs/*.md pages in DOC_FILES."""
    if not index.exists():
        return [f"{index} does not exist"]
    want = {rel.split("/", 1)[1] for rel in DOC_FILES
            if rel.startswith("docs/") and rel != "docs/README.md"}
    linked = set(re.findall(r"\]\((?:\./)?([\w-]+\.md)\)",
                            index.read_text()))
    if linked != want:
        missing = sorted(want - linked)
        extra = sorted(linked - want)
        return [f"{index}: index out of sync with DOC_FILES "
                f"(missing links: {missing}, stale links: {extra})"]
    return []


def check_bench_table(readme: pathlib.Path,
                      bench_json: pathlib.Path) -> list[str]:
    """README throughput table lines match BENCH_kernels.json."""
    if not bench_json.exists():
        return [f"{bench_json} missing (run "
                "`PYTHONPATH=src python -m benchmarks.bench_kernels`)"]
    text = readme.read_text()
    missing = [ln for ln in kernel_table(bench_json) if ln not in text]
    if missing:
        return [f"{readme}: stale/missing throughput table rows "
                f"(regenerate with `python scripts/check_docs.py "
                f"--table`):\n  " + "\n  ".join(missing)]
    return []


def main() -> int:
    errors: list[str] = []
    # names first: executing docs/engine.md's register_kernel example
    # mutates the live registry for this process
    errors += check_kernel_names(ROOT / "docs" / "engine.md")
    errors += check_kernel_names(ROOT / "README.md")
    errors += check_bench_table(ROOT / "README.md",
                                ROOT / "BENCH_kernels.json")
    errors += check_docs_index(ROOT / "docs" / "README.md")
    for rel in DOC_FILES:
        path = ROOT / rel
        if not path.exists():
            errors.append(f"{path} does not exist")
            continue
        errors += check_python_blocks(path)
    for e in errors:
        print(f"check_docs: FAIL: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({', '.join(DOC_FILES)})")
    return 1 if errors else 0


if __name__ == "__main__":
    if "--table" in sys.argv:
        print("\n".join(kernel_table(ROOT / "BENCH_kernels.json")))
        sys.exit(0)
    sys.exit(main())
