"""Build the §Dry-run and §Roofline tables of EXPERIMENTS.md from
EXPERIMENTS/dryrun_results.json, or render a scenario-grid or
observability artifact:

    PYTHONPATH=src python scripts/make_report.py
    PYTHONPATH=src python scripts/make_report.py --grid GRID_grid.json
    PYTHONPATH=src python scripts/make_report.py --obs TRACE_serve.json
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "starcoder2-15b", "recurrentgemma-9b", "llama-3.2-vision-90b",
    "xlstm-125m", "seamless-m4t-medium", "qwen3-4b", "arctic-480b",
    "deepseek-v2-236b", "qwen2-72b", "qwen3-8b",
]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x: float) -> str:
    if x >= 1e12:
        return f"{x / 1e12:.2f}TB"
    if x >= 1e9:
        return f"{x / 1e9:.2f}GB"
    if x >= 1e6:
        return f"{x / 1e6:.1f}MB"
    return f"{x / 1e3:.0f}KB"


def perf_table(base_path: str = "EXPERIMENTS/dryrun_results.json",
               perf_path: str = "EXPERIMENTS/perf_results.json") -> None:
    """§Perf: baseline vs variant roofline terms for the hillclimbed
    pairs."""
    import os
    recs = []
    for p in (base_path, perf_path):
        if os.path.exists(p):
            with open(p) as f:
                recs += json.load(f)
    targets = [("arctic-480b", "train_4k"),
               ("deepseek-v2-236b", "prefill_32k"),
               ("qwen2-72b", "train_4k")]
    print("### §Perf variants (hillclimbed pairs)\n")
    print("| pair | variant | agg | compute | memory | collective |"
          " bottleneck | coll bytes/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for a, s in targets:
        for r in recs:
            if (r.get("arch"), r.get("shape")) != (a, s):
                continue
            if r.get("mesh") != "16x16" or r.get("status") != "ok":
                continue
            t = r["roofline"]
            cb = r["hlo_analysis"]["collective_bytes_per_device"]
            print(f"| {a} × {s} | {r.get('variant', 'baseline')} | "
                  f"{r.get('agg_mode')} | {fmt_s(t['compute_s'])} | "
                  f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                  f"{t['bottleneck']} | {fmt_b(cb)} |")
    print()


def main(path: str = "EXPERIMENTS/dryrun_results.json") -> None:
    with open(path) as f:
        recs = json.load(f)
    by_key = {}
    for r in recs:
        if r.get("variant", "baseline") != "baseline":
            continue
        by_key[(r["arch"], r["shape"], r["mesh"])] = r

    # ---- dry-run status matrix ----------------------------------------
    print("### Dry-run status (lower + compile)\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"**mesh {mesh}**\n")
        print("| arch | " + " | ".join(SHAPE_ORDER) + " |")
        print("|---|" + "---|" * len(SHAPE_ORDER))
        for a in ARCH_ORDER:
            cells = []
            for s in SHAPE_ORDER:
                r = by_key.get((a, s, mesh))
                if r is None:
                    cells.append("—")
                elif r["status"] == "ok":
                    cells.append(f"OK ({r['compile_s']:.0f}s)")
                else:
                    cells.append("FAIL")
            print(f"| {a} | " + " | ".join(cells) + " |")
        print()

    # ---- roofline table (single-pod) ------------------------------------
    print("### Roofline terms per (arch × shape), 16x16 = 256 chips\n")
    print("| arch | shape | compute | memory | collective | bottleneck |"
          " MODEL_FLOPS | useful/compiled | bytes/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_key.get((a, s, "16x16"))
            if not r or r["status"] != "ok":
                continue
            t = r["roofline"]
            ma = r.get("memory_analysis", {})
            print(f"| {a} | {s} | {fmt_s(t['compute_s'])} | "
                  f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                  f"**{t['bottleneck']}** | "
                  f"{r.get('model_flops', 0):.2e} | "
                  f"{r.get('useful_flops_ratio', float('nan')):.2f} | "
                  f"{fmt_b(ma.get('per_device_total_bytes', 0))} |")
    print()

    # ---- collective mix -------------------------------------------------
    print("### Collective mix (train_4k, 16x16, baseline agg)\n")
    print("| arch | AG | AR | RS | A2A | CP | total/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        r = by_key.get((a, "train_4k", "16x16"))
        if not r or r["status"] != "ok":
            continue
        bt = r["hlo_analysis"]["collectives_by_type"]
        def g(k):
            return fmt_b(bt.get(k, {}).get("bytes", 0))
        tot = r["hlo_analysis"]["collective_bytes_per_device"]
        print(f"| {a} | {g('all-gather')} | {g('all-reduce')} | "
              f"{g('reduce-scatter')} | {g('all-to-all')} | "
              f"{g('collective-permute')} | {fmt_b(tot)} |")
    print()
    perf_table()


def grid_report(path: str = "GRID_grid.json") -> None:
    """§Grid: the scenario-grid summary table (repro.grid renderer —
    the same markdown the grid CLI writes next to its JSON)."""
    from repro.grid.report import markdown_report
    with open(path) as f:
        print(markdown_report(json.load(f)), end="")


def obs_report(*paths: str) -> None:
    """§Obs: per-stage / counter summary of Chrome traces (same
    renderer as ``python -m repro.obs``)."""
    from repro.obs import load_trace, markdown_summary, merge_events, \
        summarize
    events = []
    for p in paths or ("TRACE_serve.json",):
        events.extend(load_trace(p))
    print(markdown_summary(summarize(merge_events(events)),
                           title=", ".join(paths or ("TRACE_serve.json",))))


if __name__ == "__main__":
    if "--grid" in sys.argv:
        i = sys.argv.index("--grid")
        grid_report(*sys.argv[i + 1:i + 2])
        sys.exit(0)
    if "--obs" in sys.argv:
        i = sys.argv.index("--obs")
        obs_report(*sys.argv[i + 1:])
        sys.exit(0)
    main(*sys.argv[1:])
