#!/usr/bin/env python
"""Benchmark-artifact drift checker (fast tier; see tests/test_bench.py).

Doc drift already fails fast (scripts/check_docs.py); this gives the
machine-readable ``BENCH_*.json`` artifacts the same treatment:

1. **Presence** — every benchmark JSON the suites are supposed to
   write must exist in the repo root; a renamed or dropped artifact
   fails instead of silently vanishing from the perf trajectory.
2. **Schema** — each file's required keys and per-entry required
   fields are validated, and every numeric leaf must be finite (a NaN
   in a benchmark means the bench is broken, not slow).
3. **Bars** — the claims the artifacts exist to witness are enforced:
   packed ≥ 2x unpacked kernel throughput, fused ≥ 1x per-edge
   hierarchy wall time, the simulator's measured draw ratio within
   10% of the Prop. 1 prediction, and the 10^6-client / 100-round
   simulation under 60 s of CPU wall clock.

Exit code 0 = artifacts present, well-formed, bars met.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _finite_leaves(name: str, obj, errors: list[str],
                   path: str = "") -> None:
    """Every numeric leaf must be finite."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _finite_leaves(name, v, errors, f"{path}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _finite_leaves(name, v, errors, f"{path}[{i}]")
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        if not math.isfinite(obj):
            errors.append(f"{name}: non-finite value at {path}: {obj}")


def _require(name: str, entry: dict, key: str, fields: tuple,
             errors: list[str]) -> bool:
    missing = [f for f in fields if f not in entry]
    if missing:
        errors.append(f"{name}: entry {key!r} missing fields {missing}")
        return False
    return True


def check_kernels(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    enc = {k: v for k, v in data.items() if k.startswith("gf_encode_")}
    spd = {k: v for k, v in data.items()
           if k.startswith("packed_vs_unpacked_speedup_")}
    if not enc:
        errors.append(f"{name}: no gf_encode_* entries")
    if not spd:
        errors.append(f"{name}: no packed_vs_unpacked_speedup_* entries")
    for k, v in enc.items():
        _require(name, v, k, ("us_per_call", "symbols_per_s",
                              "bytes_per_s", "s", "K", "L"), errors)
    for k, v in spd.items():
        if _require(name, v, k, ("x",), errors) and v["x"] < 2.0:
            errors.append(f"{name}: {k} = {v['x']:.2f} < the 2x bar")
    return errors


def check_hierarchy(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    if "shape" not in data:
        errors.append(f"{name}: missing 'shape'")
    entries = {k: v for k, v in data.items()
               if k.startswith("hierarchy_E")}
    if not entries:
        errors.append(f"{name}: no hierarchy_E* entries")
    for k, v in entries.items():
        if _require(name, v, k, ("dispatches_fused", "us_fused",
                                 "dispatches_per_edge", "us_per_edge",
                                 "dispatch_ratio", "speedup"), errors):
            if v["speedup"] < 1.0:
                errors.append(f"{name}: {k} fused path slower than "
                              f"per-edge ({v['speedup']:.2f}x)")
    return errors


SIM_SCENARIO_FIELDS = (
    "population", "straggler", "rounds", "time_to_rank_k_mean",
    "time_to_all_k_mean", "time_speedup", "fednc_draws_mean",
    "fedavg_draws_mean", "draw_ratio", "predicted_draw_ratio",
    "draw_ratio_rel_err", "wall_s",
)
SIM_POPULATIONS = (10**3, 10**4, 10**5, 10**6)


def check_sim(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    cfg = data.get("config")
    if cfg is None:
        return [f"{name}: missing 'config'"]
    stragglers = cfg.get("stragglers", [])
    if len(stragglers) < 2:
        errors.append(f"{name}: needs >= 2 straggler distributions, "
                      f"got {stragglers}")
    for dist in stragglers:
        for pop in SIM_POPULATIONS:
            key = f"sim_pop{pop}_{dist}"
            entry = data.get(key)
            if entry is None:
                errors.append(f"{name}: missing scenario {key!r}")
                continue
            if not _require(name, entry, key, SIM_SCENARIO_FIELDS,
                            errors):
                continue
            if entry["draw_ratio_rel_err"] > 0.10:
                errors.append(
                    f"{name}: {key} draw ratio {entry['draw_ratio']:.3f}"
                    f" is {entry['draw_ratio_rel_err']:.1%} from the "
                    f"Prop. 1 prediction "
                    f"{entry['predicted_draw_ratio']:.3f} (> 10%)")
    scale = data.get("scale_1e6")
    if scale is None:
        errors.append(f"{name}: missing 'scale_1e6'")
    elif _require(name, scale, "scale_1e6",
                  ("population", "rounds", "wall_s", "under_60s"),
                  errors):
        if scale["population"] < 10**6 or scale["rounds"] < 100:
            errors.append(f"{name}: scale_1e6 ran {scale['population']}"
                          f" clients x {scale['rounds']} rounds; the "
                          "bar is 10^6 x 100")
        if not scale["under_60s"] or scale["wall_s"] >= 60.0:
            errors.append(f"{name}: 10^6-client sim took "
                          f"{scale['wall_s']:.1f}s (bar: < 60s)")
    if "dropout_p10" not in data:
        errors.append(f"{name}: missing 'dropout_p10' accounting")
    return errors


CHECKS = {
    "BENCH_kernels.json": check_kernels,
    "BENCH_hierarchy.json": check_hierarchy,
    "BENCH_sim.json": check_sim,
}


def main() -> int:
    errors: list[str] = []
    for fname, check in CHECKS.items():
        path = ROOT / fname
        if not path.exists():
            errors.append(f"{fname} missing (run the matching "
                          "benchmarks/ suite to regenerate it)")
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            errors.append(f"{fname}: invalid JSON: {e}")
            continue
        _finite_leaves(fname, data, errors)
        errors += check(fname, data)
    for e in errors:
        print(f"check_bench: FAIL: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench: OK ({', '.join(CHECKS)})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
