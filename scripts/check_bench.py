#!/usr/bin/env python
"""Benchmark-artifact drift checker (fast tier; see tests/test_bench.py).

Doc drift already fails fast (scripts/check_docs.py); this gives the
machine-readable ``BENCH_*.json`` artifacts the same treatment:

1. **Presence** — every benchmark JSON the suites are supposed to
   write must exist in the repo root; a renamed or dropped artifact
   fails instead of silently vanishing from the perf trajectory.
2. **Schema** — each file's required keys and per-entry required
   fields are validated, and every numeric leaf must be finite (a NaN
   in a benchmark means the bench is broken, not slow).
3. **Bars** — the claims the artifacts exist to witness are enforced:
   packed ≥ 2x unpacked kernel throughput, seeded ≥ 0.9x materialized
   throughput at matched shapes with wire overhead exactly
   (4+L)/(K+L), fused ≥ 1x per-edge hierarchy wall time, the
   simulator's measured draw ratio within 10% of the Prop. 1
   prediction, the 10^6-client / 100-round simulation under 60 s of
   CPU wall clock, and the decode server's continuous batching ≥ 1.5x
   sequential per-job ingest at ≥ 8 concurrent jobs with byte-identical
   payloads (``BENCH_serve.json``; ``BENCH_serve_*.json`` smoke
   artifacts are schema-checked with the bar relaxed), and the
   security bars (``BENCH_security.json``): zero full leaks below
   full edge capture, measured leak probability within its binomial
   tolerance of the closed form, byzantine detection ≥ 0.99 with zero
   undetected bad decodes, every replayed seed header flagged
   (``BENCH_security_*.json`` smoke artifacts relax the full-tier
   detection/recovery bars only).

The scenario-grid artifacts (``GRID_*.json``, schema
``fednc-grid-v1`` from ``repro.grid``) get the same treatment:
``GRID_grid.json`` (the full grid, ``benchmarks/bench_grid.py``) must
exist and carry the delay-reordered sweep (FedAvg inflation beyond
K·H(K) above its bar) and the compute-coupling section (coupled decode
clock strictly dominating the network-only schedule); any other
``GRID_*.json`` in the root (e.g. the CI smoke artifact) is
schema-checked too — axes (including the ``adversary`` coordinate),
per-scenario seed, draw-ratio fields, and the per-scenario
``per_stage`` wall breakdown from ``repro.obs``; ``GRID_smoke.json``
must additionally carry >= 2 active-adversary cells.

Observability artifacts ride the same gate: ``BENCH_serve*.json``
must embed a valid ``fednc-metrics-v1`` snapshot (queue-depth gauge,
ingest-batch + job-latency histograms), and any ``TRACE_*.json`` in
the root must be valid Chrome trace-event JSON (schema
``fednc-trace-v1``).  The rules are restated here standalone — this
script must keep running without ``repro`` importable.

Exit code 0 = artifacts present, well-formed, bars met.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _finite_leaves(name: str, obj, errors: list[str],
                   path: str = "") -> None:
    """Every numeric leaf must be finite."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _finite_leaves(name, v, errors, f"{path}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _finite_leaves(name, v, errors, f"{path}[{i}]")
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        if not math.isfinite(obj):
            errors.append(f"{name}: non-finite value at {path}: {obj}")


def _require(name: str, entry: dict, key: str, fields: tuple,
             errors: list[str]) -> bool:
    missing = [f for f in fields if f not in entry]
    if missing:
        errors.append(f"{name}: entry {key!r} missing fields {missing}")
        return False
    return True


#: seeded encode must stay within 10% of its materialized sibling at
#: matched shapes — regenerating coefficients in-kernel is supposed to
#: be (at least nearly) free next to the O(K·L) field products
SEEDED_THROUGHPUT_BAR = 0.9
#: wire-overhead rows must exist at these generation sizes
SEEDED_WIRE_KS = (32, 128, 512)


def check_kernels(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    enc = {k: v for k, v in data.items() if k.startswith("gf_encode_")}
    spd = {k: v for k, v in data.items()
           if k.startswith("packed_vs_unpacked_speedup_")}
    sed = {k: v for k, v in data.items()
           if k.startswith("seeded_vs_materialized_")}
    if not enc:
        errors.append(f"{name}: no gf_encode_* entries")
    if not any("_seeded_" in k for k in enc):
        errors.append(f"{name}: no seeded gf_encode_* entries")
    if not spd:
        errors.append(f"{name}: no packed_vs_unpacked_speedup_* entries")
    if not sed:
        errors.append(f"{name}: no seeded_vs_materialized_* entries")
    for k, v in enc.items():
        _require(name, v, k, ("us_per_call", "symbols_per_s",
                              "bytes_per_s", "s", "K", "L"), errors)
    for k, v in spd.items():
        if _require(name, v, k, ("x",), errors) and v["x"] < 2.0:
            errors.append(f"{name}: {k} = {v['x']:.2f} < the 2x bar")
    for k, v in sed.items():
        if _require(name, v, k, ("x",), errors) \
                and v["x"] < SEEDED_THROUGHPUT_BAR:
            errors.append(f"{name}: {k} = {v['x']:.2f} < the "
                          f"{SEEDED_THROUGHPUT_BAR}x seeded bar")
    for Kw in SEEDED_WIRE_KS:
        k = f"seeded_wire_overhead_K{Kw}"
        v = data.get(k)
        if v is None:
            errors.append(f"{name}: missing {k!r}")
            continue
        if not _require(name, v, k, ("K", "L", "s", "materialized_bytes",
                                     "seeded_bytes", "ratio"), errors):
            continue
        # the claim the seeded family exists for: header bytes drop
        # from K·s/8 to 4, so the ratio must equal (4 + L·s/8) over
        # (K·s/8 + L·s/8) exactly (pure arithmetic, no tolerance)
        lb = v["L"] * v["s"] / 8
        expect = (4 + lb) / (v["K"] * v["s"] / 8 + lb)
        if abs(v["ratio"] - expect) > 1e-12 or v["ratio"] >= 1.0:
            errors.append(f"{name}: {k} ratio {v['ratio']:.6f} != "
                          f"(4+L)/(K+L) = {expect:.6f}")
    return errors


def check_hierarchy(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    if "shape" not in data:
        errors.append(f"{name}: missing 'shape'")
    entries = {k: v for k, v in data.items()
               if k.startswith("hierarchy_E")}
    if not entries:
        errors.append(f"{name}: no hierarchy_E* entries")
    for k, v in entries.items():
        if _require(name, v, k, ("dispatches_fused", "us_fused",
                                 "dispatches_per_edge", "us_per_edge",
                                 "dispatch_ratio", "speedup"), errors):
            if v["speedup"] < 1.0:
                errors.append(f"{name}: {k} fused path slower than "
                              f"per-edge ({v['speedup']:.2f}x)")
    return errors


SIM_SCENARIO_FIELDS = (
    "population", "straggler", "rounds", "time_to_rank_k_mean",
    "time_to_all_k_mean", "time_speedup", "fednc_draws_mean",
    "fedavg_draws_mean", "draw_ratio", "predicted_draw_ratio",
    "draw_ratio_rel_err", "wall_s",
)
SIM_POPULATIONS = (10**3, 10**4, 10**5, 10**6)


def check_sim(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    cfg = data.get("config")
    if cfg is None:
        return [f"{name}: missing 'config'"]
    stragglers = cfg.get("stragglers", [])
    if len(stragglers) < 2:
        errors.append(f"{name}: needs >= 2 straggler distributions, "
                      f"got {stragglers}")
    for dist in stragglers:
        for pop in SIM_POPULATIONS:
            key = f"sim_pop{pop}_{dist}"
            entry = data.get(key)
            if entry is None:
                errors.append(f"{name}: missing scenario {key!r}")
                continue
            if not _require(name, entry, key, SIM_SCENARIO_FIELDS,
                            errors):
                continue
            if entry["draw_ratio_rel_err"] > 0.10:
                errors.append(
                    f"{name}: {key} draw ratio {entry['draw_ratio']:.3f}"
                    f" is {entry['draw_ratio_rel_err']:.1%} from the "
                    f"Prop. 1 prediction "
                    f"{entry['predicted_draw_ratio']:.3f} (> 10%)")
    scale = data.get("scale_1e6")
    if scale is None:
        errors.append(f"{name}: missing 'scale_1e6'")
    elif _require(name, scale, "scale_1e6",
                  ("population", "rounds", "wall_s", "under_60s"),
                  errors):
        if scale["population"] < 10**6 or scale["rounds"] < 100:
            errors.append(f"{name}: scale_1e6 ran {scale['population']}"
                          f" clients x {scale['rounds']} rounds; the "
                          "bar is 10^6 x 100")
        if not scale["under_60s"] or scale["wall_s"] >= 60.0:
            errors.append(f"{name}: 10^6-client sim took "
                          f"{scale['wall_s']:.1f}s (bar: < 60s)")
    if "dropout_p10" not in data:
        errors.append(f"{name}: missing 'dropout_p10' accounting")
    return errors


#: schema tags written by repro.obs — validated here WITHOUT importing
#: repro (tests/test_bench.py runs this checker standalone, no
#: PYTHONPATH), so the rules are restated rather than shared
METRICS_SCHEMA = "fednc-metrics-v1"
TRACE_SCHEMA = "fednc-trace-v1"


def _check_number(name: str, key: str, field: str, v, errors,
                  allow_none: bool = False) -> bool:
    if v is None and allow_none:
        return True
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        errors.append(f"{name}: {key} field {field!r} is not a number: "
                      f"{v!r}")
        return False
    return True


def check_metrics_doc(name: str, doc, key: str = "metrics",
                      require: tuple = ()) -> list[str]:
    """Validate one ``fednc-metrics-v1`` snapshot (repro.obs.metrics)."""
    errors: list[str] = []
    if not isinstance(doc, dict) or doc.get("schema") != METRICS_SCHEMA:
        return [f"{name}: {key} schema "
                f"{doc.get('schema') if isinstance(doc, dict) else doc!r}"
                f" != {METRICS_SCHEMA!r}"]
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return [f"{name}: {key} missing the 'metrics' mapping"]
    for req, kind in require:
        if metrics.get(req, {}).get("type") != kind:
            errors.append(f"{name}: {key} missing required {kind} "
                          f"{req!r}")
    for mname, m in metrics.items():
        mk = f"{key}[{mname}]"
        t = m.get("type") if isinstance(m, dict) else None
        if t == "counter":
            _check_number(name, mk, "value", m.get("value"), errors)
        elif t == "gauge":
            if _require(name, m, mk, ("last", "min", "max", "sum",
                                      "count"), errors):
                for f in ("last", "min", "max"):
                    _check_number(name, mk, f, m[f], errors,
                                  allow_none=True)
        elif t == "histogram":
            if not _require(name, m, mk, ("bounds", "counts", "count",
                                          "sum", "min", "max"), errors):
                continue
            bounds, counts = m["bounds"], m["counts"]
            if any(b >= a for b, a in zip(bounds, bounds[1:],
                                          strict=False)) \
                    or not bounds:
                errors.append(f"{name}: {mk} bounds are not strictly "
                              "ascending")
            if len(counts) != len(bounds) + 1:
                errors.append(f"{name}: {mk} has {len(counts)} counts "
                              f"for {len(bounds)} bounds (want "
                              "len(bounds)+1, overflow bucket last)")
            elif sum(counts) != m["count"]:
                errors.append(f"{name}: {mk} count {m['count']} != "
                              f"sum(counts) {sum(counts)}")
        else:
            errors.append(f"{name}: {mk} has unknown metric type {t!r}")
    return errors


def check_trace(name: str, data) -> list[str]:
    """Validate a Chrome trace-event document (repro.obs.trace)."""
    errors: list[str] = []
    if not isinstance(data, dict):
        return [f"{name}: trace document is not an object"]
    if data.get("otherData", {}).get("schema") != TRACE_SCHEMA:
        errors.append(f"{name}: otherData.schema != {TRACE_SCHEMA!r}")
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return errors + [f"{name}: traceEvents missing or empty"]
    for i, ev in enumerate(events):
        key = f"traceEvents[{i}]"
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            errors.append(f"{name}: {key} missing 'ph'/'name'")
            continue
        if ev["ph"] == "M":      # metadata carries no timestamp
            continue
        for f in ("ts", "pid", "tid"):
            if f not in ev:
                errors.append(f"{name}: {key} ({ev['ph']!r} "
                              f"{ev['name']!r}) missing {f!r}")
            else:
                _check_number(name, key, f, ev[f], errors)
        if ev["ph"] == "X":
            if not _check_number(name, key, "dur", ev.get("dur"),
                                 errors) or ev["dur"] < 0:
                errors.append(f"{name}: {key} complete event has bad "
                              f"dur {ev.get('dur')!r}")
        elif ev["ph"] == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float))
                    and not isinstance(v, bool) for v in args.values()):
                errors.append(f"{name}: {key} counter event needs "
                              "non-empty numeric args")
    return errors


SERVE_MODES = ("serve_batched", "serve_sequential")
SERVE_ENTRY_FIELDS = (
    "mode", "jobs", "completed", "packets", "ticks", "dispatches",
    "max_concurrent", "wall_s", "packets_per_s", "p50_latency_s",
    "p99_latency_s",
)
#: continuous batching must beat per-job dispatch by this much...
SERVE_SPEEDUP_BAR = 1.5
#: ...with at least this many jobs genuinely in flight
SERVE_MIN_CONCURRENT = 8


def check_serve(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    cfg = data.get("config")
    if cfg is None:
        return [f"{name}: missing 'config'"]
    smoke = bool(cfg.get("smoke"))
    for mode in SERVE_MODES:
        entry = data.get(mode)
        if entry is None:
            errors.append(f"{name}: missing {mode!r}")
            continue
        if not _require(name, entry, mode, SERVE_ENTRY_FIELDS, errors):
            continue
        if entry["completed"] < entry["jobs"]:
            errors.append(f"{name}: {mode} decoded only "
                          f"{entry['completed']}/{entry['jobs']} jobs")
        if entry["p99_latency_s"] < entry["p50_latency_s"]:
            errors.append(f"{name}: {mode} p99 < p50 latency")
    if data.get("payloads_match") is not True:
        errors.append(f"{name}: batched and sequential decodes are "
                      "not byte-identical (payloads_match != true)")
    errors += check_metrics_doc(
        name, data.get("metrics"), require=(
            ("serve.queue_depth", "gauge"),
            ("serve.ingest_batch", "histogram"),
            ("serve.job_latency_s", "histogram")))
    ratio = data.get("batched_vs_sequential")
    if ratio is None:
        return errors + [f"{name}: missing 'batched_vs_sequential'"]
    if not _require(name, ratio, "batched_vs_sequential",
                    ("x", "concurrent_jobs"), errors) or smoke:
        return errors
    if ratio["concurrent_jobs"] < SERVE_MIN_CONCURRENT:
        errors.append(
            f"{name}: only {ratio['concurrent_jobs']} concurrent jobs "
            f"(bar: >= {SERVE_MIN_CONCURRENT})")
    if ratio["x"] < SERVE_SPEEDUP_BAR:
        errors.append(
            f"{name}: batched ingest {ratio['x']:.2f}x sequential "
            f"(bar: >= {SERVE_SPEEDUP_BAR}x)")
    return errors


#: byzantine detection must flag at least this share of corrupted
#: rounds (full tier; the rest are rank failures, also rejections)
SECURITY_DETECTION_BAR = 0.99


def check_security(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    cfg = data.get("config")
    if cfg is None:
        return [f"{name}: missing 'config'"]
    smoke = bool(cfg.get("smoke"))
    K = cfg.get("K")

    sweep = data.get("eavesdrop_edge_sweep")
    if sweep is None:
        errors.append(f"{name}: missing 'eavesdrop_edge_sweep'")
    elif _require(name, sweep, "eavesdrop_edge_sweep",
                  ("edges", "K", "trials", "entries"), errors):
        for e in sweep["entries"]:
            key = f"edge_sweep[tapped={e.get('tapped_edges')}]"
            if not _require(name, e, key,
                            ("tapped_edges", "rank_mean", "rank_max",
                             "full_leak_rate"), errors):
                continue
            if e["tapped_edges"] < sweep["edges"]:
                # the structural rank wall: < E edge links span < K
                # columns, so a full leak is *impossible*, not unlikely
                if e["full_leak_rate"] > 0 or e["rank_max"] >= sweep["K"]:
                    errors.append(
                        f"{name}: {key} leaked (rank_max="
                        f"{e['rank_max']}, K={sweep['K']}) below full "
                        "edge capture")
            elif e["full_leak_rate"] < 1.0:
                errors.append(f"{name}: {key} full edge capture only "
                              f"leaked {e['full_leak_rate']:.2f} of "
                              "trials (want 1.0)")

    leak = data.get("leak_probability")
    if leak is None:
        errors.append(f"{name}: missing 'leak_probability'")
    elif not leak.get("entries"):
        errors.append(f"{name}: leak_probability has no entries")
    else:
        for e in leak["entries"]:
            key = (f"leak[p={e.get('p_intercept')},"
                   f"c={e.get('colluders')}]")
            if not _require(name, e, key,
                            ("n", "K", "colluders", "p_intercept",
                             "measured", "closed_form", "abs_err",
                             "tol", "rank_wall_violations"), errors):
                continue
            if e["rank_wall_violations"] != 0:
                errors.append(f"{name}: {key} reported "
                              f"{e['rank_wall_violations']} trials "
                              "leaking below K independent rows")
            if e["abs_err"] > e["tol"]:
                errors.append(
                    f"{name}: {key} measured leak {e['measured']:.4f} "
                    f"is {e['abs_err']:.4f} from the closed form "
                    f"{e['closed_form']:.4f} (tol {e['tol']:.4f})")

    byz = data.get("byzantine_detection")
    if byz is None:
        errors.append(f"{name}: missing 'byzantine_detection'")
    elif not byz.get("entries"):
        errors.append(f"{name}: byzantine_detection has no entries")
    else:
        for e in byz["entries"]:
            key = f"byzantine[rate={e.get('rate')}]"
            if not _require(name, e, key,
                            ("rate", "rounds", "corrupted_rounds",
                             "detected", "detection_rate",
                             "undetected_bad_decodes", "recovery"),
                            errors):
                continue
            if e["undetected_bad_decodes"] != 0:
                errors.append(f"{name}: {key} accepted "
                              f"{e['undetected_bad_decodes']} wrong "
                              "decodes past verification")
            rec = e["recovery"]
            _require(name, rec, f"{key} recovery",
                     ("rounds", "flagged", "accepted", "correct"),
                     errors)
            if smoke:
                continue
            if e["corrupted_rounds"] > 0 \
                    and e["detection_rate"] < SECURITY_DETECTION_BAR:
                errors.append(
                    f"{name}: {key} detection rate "
                    f"{e['detection_rate']:.2f} < the "
                    f"{SECURITY_DETECTION_BAR} bar")
            if not (rec.get("accepted") and rec.get("correct")):
                errors.append(f"{name}: {key} recovery loop never "
                              "reached an accepted correct decode")

    rep = data.get("replay_detection")
    if rep is None:
        errors.append(f"{name}: missing 'replay_detection'")
    elif _require(name, rep, "replay_detection",
                  ("replays", "flagged"), errors):
        if rep["flagged"] != rep["replays"]:
            errors.append(
                f"{name}: replay_detection flagged only "
                f"{rep['flagged']}/{rep['replays']} replayed headers")
    if K is None:
        errors.append(f"{name}: config missing 'K'")
    return errors


GRID_SCHEMA = "fednc-grid-v1"
GRID_AXES = ("strategy", "straggler", "delay_spread", "p_dropout",
             "population", "kernel", "adversary")
GRID_SIM_STRATEGIES = ("fednc_stream", "fednc_stages", "fedavg")
GRID_DRAW_FIELDS = ("fednc_draws_mean", "fedavg_draws_mean",
                    "draw_ratio")
GRID_ENGINE_FIELDS = ("kernel_resolved", "seeded", "decode_rate",
                      "wire_bytes_per_packet", "wire_bytes_per_round",
                      "wire_overhead_ratio")


def check_grid(name: str, data: dict) -> list[str]:
    errors: list[str] = []
    if data.get("schema") != GRID_SCHEMA:
        return [f"{name}: schema {data.get('schema')!r} != "
                f"{GRID_SCHEMA!r}"]
    cfg = data.get("config")
    if not isinstance(cfg, dict):
        return [f"{name}: missing 'config'"]
    if not isinstance(cfg.get("base_seed"), int):
        errors.append(f"{name}: config.base_seed missing/not int")
    axes = cfg.get("axes", {})
    missing_axes = [a for a in GRID_AXES if a not in axes]
    if missing_axes:
        errors.append(f"{name}: config.axes missing {missing_axes}")
    scenarios = data.get("scenarios")
    if not scenarios:
        return errors + [f"{name}: no scenarios"]
    for key, entry in scenarios.items():
        if not _require(name, entry, key, ("seed", "axes", "rounds",
                                           "wall_s", "per_stage"),
                        errors):
            continue
        if not isinstance(entry["seed"], int):
            errors.append(f"{name}: {key} seed is not an int")
        # per-stage wall breakdown from the scenario-local tracer:
        # {stage name -> seconds}, never empty (every strategy emits
        # at least one leaf span)
        stages = entry["per_stage"]
        if not isinstance(stages, dict) or not stages or not all(
                isinstance(v, (int, float)) and not isinstance(v, bool)
                for v in stages.values()):
            errors.append(f"{name}: {key} per_stage is not a non-empty "
                          "{stage: seconds} mapping")
        ax = entry["axes"]
        missing = [a for a in GRID_AXES if a not in ax]
        if missing:
            errors.append(f"{name}: {key} axes missing {missing}")
            continue
        if ax["strategy"] in GRID_SIM_STRATEGIES:
            _require(name, entry, key, GRID_DRAW_FIELDS, errors)
            # null draw stats are legal only when dropout blocked the
            # FedAvg collector in every round
            if (entry.get("draw_ratio") is None
                    and not ax["p_dropout"] > 0):
                errors.append(f"{name}: {key} has null draw_ratio "
                              "without dropout")
        elif ax["strategy"] == "engine":
            if not _require(name, entry, key, GRID_ENGINE_FIELDS,
                            errors):
                continue
            if entry["seeded"] and entry["wire_overhead_ratio"] >= 1.0:
                errors.append(
                    f"{name}: {key} is a seeded cell but its wire "
                    f"overhead ratio {entry['wire_overhead_ratio']:.4f}"
                    " did not shrink below 1")
            # a byzantine cell legitimately rejects corrupted rounds,
            # so decode_rate < 1 is only an error on a clean channel
            byzantine = str(ax.get("adversary",
                                   "none")).startswith("byzantine")
            if entry["decode_rate"] < 1.0 and not ax["p_dropout"] > 0 \
                    and not byzantine:
                errors.append(
                    f"{name}: {key} dropped rounds "
                    f"(decode_rate={entry['decode_rate']:.2f}) on a "
                    "lossless channel")
    if cfg.get("full"):
        errors += _check_grid_full(name, data)
    return errors


def _check_grid_full(name: str, data: dict) -> list[str]:
    """The bars only the full grid (bench_grid.py) must clear."""
    errors: list[str] = []
    sweep = data.get("delay_sweep")
    if sweep is None:
        errors.append(f"{name}: full grid missing 'delay_sweep'")
    elif _require(name, sweep, "delay_sweep",
                  ("spreads", "kh_k", "fedavg_draws_mean", "inflation",
                   "draw_ratio", "inflation_bar"), errors):
        n = len(sweep["spreads"])
        if any(len(sweep[k]) != n for k in
               ("fedavg_draws_mean", "inflation", "draw_ratio")):
            errors.append(f"{name}: delay_sweep arrays disagree on "
                          "length")
        elif sweep["inflation"][-1] <= sweep["inflation_bar"]:
            errors.append(
                f"{name}: delay-reordered FedAvg inflation "
                f"{sweep['inflation'][-1]:.2f}x does not exceed the "
                f"{sweep['inflation_bar']}x bar — the reordering "
                "regime stopped hurting the blind-box collector?")
    cc = data.get("compute_coupling")
    if cc is None:
        errors.append(f"{name}: full grid missing 'compute_coupling'")
    elif _require(name, cc, "compute_coupling",
                  ("sim_time_mean", "sim_time_network_mean",
                   "dominates"), errors):
        if not cc["dominates"]:
            errors.append(
                f"{name}: compute-coupled decode clock does not "
                "strictly dominate the network-only schedule")
    return errors


#: the CI smoke grid must exercise the adversary axis: at least this
#: many cells with an active (non-"none") adversary coordinate
SMOKE_MIN_ADVERSARY_CELLS = 2


def check_grid_smoke(name: str, data: dict) -> list[str]:
    """The CI smoke grid: the base schema + adversary-axis coverage."""
    errors = check_grid(name, data)
    cells = [k for k, e in data.get("scenarios", {}).items()
             if e.get("axes", {}).get("adversary", "none") != "none"]
    if len(cells) < SMOKE_MIN_ADVERSARY_CELLS:
        errors.append(
            f"{name}: only {len(cells)} adversary cells (bar: >= "
            f"{SMOKE_MIN_ADVERSARY_CELLS}; run `python -m repro.grid "
            "--smoke` to regenerate)")
    return errors


CHECKS = {
    "BENCH_kernels.json": check_kernels,
    "BENCH_hierarchy.json": check_hierarchy,
    "BENCH_sim.json": check_sim,
    "BENCH_serve.json": check_serve,
    "BENCH_security.json": check_security,
    "GRID_grid.json": check_grid,
    "GRID_smoke.json": check_grid_smoke,
}


def main() -> int:
    errors: list[str] = []
    # extra GRID_*/BENCH_serve_* artifacts (smoke runs, ad-hoc
    # sweeps) are optional but must be well-formed when present
    extra = sorted(p.name for p in ROOT.glob("GRID_*.json")
                   if p.name not in CHECKS)
    checks = dict(CHECKS)
    checks.update({fname: check_grid for fname in extra})
    checks.update({p.name: check_serve
                   for p in sorted(ROOT.glob("BENCH_serve_*.json"))
                   if p.name not in CHECKS})
    checks.update({p.name: check_security
                   for p in sorted(ROOT.glob("BENCH_security_*.json"))
                   if p.name not in CHECKS})
    # Chrome traces (bench_serve --trace, repro.grid --trace) are
    # optional artifacts but must be valid trace-event JSON when present
    checks.update({p.name: check_trace
                   for p in sorted(ROOT.glob("TRACE_*.json"))})
    for fname, check in checks.items():
        path = ROOT / fname
        if not path.exists():
            errors.append(f"{fname} missing (run the matching "
                          "benchmarks/ suite to regenerate it)")
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            errors.append(f"{fname}: invalid JSON: {e}")
            continue
        _finite_leaves(fname, data, errors)
        errors += check(fname, data)
    for e in errors:
        print(f"check_bench: FAIL: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench: OK ({', '.join(checks)})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
