"""repro.obs: trace round-trip, metric merge laws, no-op neutrality.

The observability layer must never change what it observes: the
NULL_TRACER path has to be bit-exact with the traced path, snapshots
must merge associatively (grid workers reduce in arbitrary order),
and the serialized artifacts must stay valid Chrome trace-event JSON
(the contract scripts/check_bench.py re-checks standalone).
"""
import json
import random

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.gf import get_field
from repro.engine import CodingEngine, EngineConfig
from repro.serve import poisson_multitenant_trace, serve_trace

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def _null_tracer():
    """Every test starts and ends with tracing disabled."""
    obs.set_tracer(obs.NULL_TRACER)
    yield
    obs.set_tracer(obs.NULL_TRACER)


# ---------------------------------------------------------------------------
# Trace document round-trip
# ---------------------------------------------------------------------------


def test_trace_round_trips_as_valid_chrome_json(tmp_path):
    tr = obs.Tracer(process_name="test")
    with tr.span("outer", cat="t", k=3) as sp:
        with tr.span("inner", cat="t"):
            pass
        sp.set(done=True)
    tr.instant("mark", cat="t", x=1)
    tr.counter("depth", 7)
    path = tr.save(tmp_path / "TRACE_t.json")

    doc = json.loads(path.read_text())
    assert doc["otherData"]["schema"] == obs.TRACE_SCHEMA
    events = obs.load_trace(path)
    assert obs.validate_trace(events) == []
    by_ph = {}
    for ev in events:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert {e["name"] for e in by_ph["X"]} == {"outer", "inner"}
    outer = next(e for e in by_ph["X"] if e["name"] == "outer")
    inner = next(e for e in by_ph["X"] if e["name"] == "inner")
    # the span nesting holds on the timeline, and set() args landed
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"k": 3, "done": True}
    assert by_ph["i"][0]["s"] == "t"
    assert by_ph["C"][0]["args"] == {"depth": 7.0}
    assert by_ph["M"][0]["args"]["name"] == "test"


def test_validate_trace_rejects_malformed_events():
    assert obs.validate_trace([{"ph": "X"}])      # no name
    assert obs.validate_trace(
        [{"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0.0}])
    assert obs.validate_trace(
        [{"name": "q", "ph": "C", "ts": 0.0, "pid": 1, "tid": 0,
          "args": {"q": "high"}}])
    # metadata events are exempt from ts/pid/tid
    assert obs.validate_trace(
        [{"name": "process_name", "ph": "M", "args": {"name": "w"}}]) \
        == []


def test_merge_keeps_pid_lanes_and_orders_by_time():
    a = [{"name": "s", "ph": "X", "ts": 10.0, "dur": 1.0, "pid": 1,
          "tid": 0}]
    b = [{"name": "process_name", "ph": "M", "pid": 2, "tid": 0,
          "args": {"name": "w"}},
         {"name": "s", "ph": "X", "ts": 5.0, "dur": 1.0, "pid": 2,
          "tid": 0}]
    merged = obs.merge_events(a, b)
    assert [e["ph"] for e in merged] == ["M", "X", "X"]
    assert [e.get("pid") for e in merged] == [2, 2, 1]
    assert obs.summarize(merged)["processes"] == 2


def test_stage_totals_excludes_envelopes():
    evs = [{"name": "outer", "ph": "X", "ts": 0, "dur": 5e6, "pid": 1,
            "tid": 0},
           {"name": "leaf", "ph": "X", "ts": 0, "dur": 2e6, "pid": 1,
            "tid": 0},
           {"name": "leaf", "ph": "X", "ts": 2e6, "dur": 1e6, "pid": 1,
            "tid": 0}]
    assert obs.stage_totals(evs, exclude=("outer",)) == {"leaf": 3.0}


# ---------------------------------------------------------------------------
# Metrics: snapshot + merge algebra
# ---------------------------------------------------------------------------


def _random_registry(rng: random.Random) -> obs.MetricsRegistry:
    reg = obs.MetricsRegistry()
    c = reg.counter("c")
    for _ in range(rng.randrange(4)):
        c.inc(rng.randrange(1, 10))
    g = reg.gauge("g")
    for _ in range(rng.randrange(4)):
        g.set(rng.uniform(-5, 5))
    h = reg.histogram("h", bounds=(1.0, 10.0, 100.0))
    for _ in range(rng.randrange(6)):
        h.observe(rng.uniform(0.1, 500.0))
    return reg


def _seeded_snapshots(seed: int, n: int = 3) -> list:
    rng = random.Random(seed)
    return [_random_registry(rng).snapshot() for _ in range(n)]


def _close(x, y, path="") -> None:
    """Snapshots must agree exactly on structure/ints and up to float
    rounding on sums (addition reassociates across merge orders)."""
    assert type(x) is type(y), f"{path}: {type(x)} vs {type(y)}"
    if isinstance(x, dict):
        assert x.keys() == y.keys(), path
        for k in x:
            _close(x[k], y[k], f"{path}/{k}")
    elif isinstance(x, list):
        assert len(x) == len(y), path
        for i, (a, b) in enumerate(zip(x, y, strict=True)):
            _close(a, b, f"{path}[{i}]")
    elif isinstance(x, float):
        assert x == pytest.approx(y, rel=1e-9), path
    else:
        assert x == y, path


def _merge_associative(snaps) -> None:
    a, b, c = snaps
    left = obs.merge_snapshots(obs.merge_snapshots(a, b), c)
    right = obs.merge_snapshots(a, obs.merge_snapshots(b, c))
    _close(left, right)
    flat = obs.merge_snapshots(a, b, c)
    assert flat == left


@pytest.mark.parametrize("seed", range(8))
def test_merge_snapshots_associative(seed):
    _merge_associative(_seeded_snapshots(seed))


if HAVE_HYPOTHESIS:                                    # pragma: no branch
    @settings(max_examples=50, deadline=None)
    @given(st.integers(min_value=0, max_value=10**9))
    def test_merge_snapshots_associative_hypothesis(seed):
        _merge_associative(_seeded_snapshots(seed))


def test_merged_snapshot_matches_single_registry_totals():
    reg1, reg2 = obs.MetricsRegistry(), obs.MetricsRegistry()
    both = obs.MetricsRegistry()
    for v, reg in ((3.0, reg1), (7.0, reg2)):
        reg.counter("n").inc(int(v))
        reg.gauge("q").set(v)
        reg.histogram("lat").observe(v)
        both.counter("n").inc(int(v))
        both.gauge("q").set(v)
        both.histogram("lat").observe(v)
    merged = obs.merge_snapshots(reg1.snapshot(), reg2.snapshot())
    assert merged == both.snapshot()


def test_histogram_merge_rejects_mismatched_bounds():
    a = obs.Histogram("h", bounds=(1.0, 2.0)).snapshot()
    b = obs.Histogram("h", bounds=(1.0, 3.0)).snapshot()
    da = {"schema": obs.METRICS_SCHEMA, "metrics": {"h": a}}
    db = {"schema": obs.METRICS_SCHEMA, "metrics": {"h": b}}
    with pytest.raises(ValueError):
        obs.merge_snapshots(da, db)


def test_histogram_percentile_brackets_samples():
    h = obs.Histogram("lat", bounds=obs.exp_buckets())
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    assert h.percentile(1.0) == pytest.approx(0.1)
    assert 0.001 <= h.percentile(0.5) <= 0.1


# ---------------------------------------------------------------------------
# No-op neutrality: tracing must not change what it observes
# ---------------------------------------------------------------------------


def test_engine_round_bit_exact_tracing_on_and_off():
    s, K, L = 8, 6, 257
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(2), (K, L))
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed",
                                    chunk_l=64, extra_tuples=2))
    off = eng.round(P, jax.random.PRNGKey(9))
    tr = obs.set_tracer(obs.Tracer())
    try:
        on = eng.round(P, jax.random.PRNGKey(9))
    finally:
        obs.set_tracer(obs.NULL_TRACER)
    assert off.ok and on.ok
    np.testing.assert_array_equal(np.asarray(off.packets),
                                  np.asarray(on.packets))
    # the traced run really did record the per-stage spans
    names = {e["name"] for e in tr.events}
    assert {"engine.round", "engine.encode", "engine.invert"} <= names


def test_serve_trace_bit_exact_tracing_on_and_off():
    trace = poisson_multitenant_trace(4, 6, 32, s=8, rate=4.0,
                                      extra_packets=2, seed=3)
    off = serve_trace(trace, slots=4, g_tick=4, batched=True)
    tr = obs.set_tracer(obs.Tracer())
    try:
        on = serve_trace(trace, slots=4, g_tick=4, batched=True)
    finally:
        obs.set_tracer(obs.NULL_TRACER)
    assert [(c.job, c.arrivals, c.payload_sha)
            for c in off.completions] \
        == [(c.job, c.arrivals, c.payload_sha) for c in on.completions]
    assert obs.validate_trace(list(tr.events)) == []
    assert {e["name"] for e in tr.events} >= {"serve.ingest",
                                              "serve.queue_depth"}


def test_serve_metrics_snapshot_is_published():
    trace = poisson_multitenant_trace(4, 6, 32, s=8, rate=4.0,
                                      extra_packets=2, seed=3)
    rep = serve_trace(trace, slots=4, g_tick=4, batched=True)
    m = rep.metrics["metrics"]
    assert rep.metrics["schema"] == obs.METRICS_SCHEMA
    assert m["serve.ticks"]["value"] == rep.ticks
    assert m["serve.packets_ingested"]["value"] == rep.packets_ingested
    assert m["serve.job_latency_s"]["count"] == len(rep.completions)
    assert m["serve.queue_depth"]["count"] == rep.ticks


def test_disabled_tracer_overhead_under_2pct_of_serve_smoke():
    """The instrumentation bar: with tracing off, the per-call cost of
    the no-op span/instant/counter paths, times the number of events a
    traced smoke replay actually emits, must stay under 2% of that
    replay's wall time."""
    trace = poisson_multitenant_trace(6, 8, 64, s=8, rate=4.0,
                                      extra_packets=3, seed=5)
    serve_trace(trace, slots=4, g_tick=4, batched=True)   # jit warmup
    off = serve_trace(trace, slots=4, g_tick=4, batched=True)

    tr = obs.set_tracer(obs.Tracer())
    try:
        serve_trace(trace, slots=4, g_tick=4, batched=True)
    finally:
        obs.set_tracer(obs.NULL_TRACER)
    n_events = len(tr.events)

    null = obs.get_tracer()
    n = 100_000
    with obs.timed("overhead.null_span", tracer=None) as sw:
        for _ in range(n):
            with null.span("x", cat="t", i=0):
                pass
            null.instant("x")
            null.counter("x", 1)
    per_call = sw.dur_s / n            # one span + instant + counter
    overhead = per_call * n_events
    assert overhead < 0.02 * off.wall_s, (
        f"no-op instrumentation {overhead * 1e6:.1f}us vs "
        f"{off.wall_s * 1e3:.1f}ms replay ({n_events} events)")


# ---------------------------------------------------------------------------
# Grid: scenario-local tracing + spawn-context merge
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_run_grid_jobs2_merges_worker_traces(tmp_path):
    """run_grid(jobs=2) spawn workers each record into their own
    tracer; the parent must merge the lanes into one valid trace with
    one pid per worker and per-scenario per_stage breakdowns."""
    from repro.grid import GridAxes, run_grid
    pytest.importorskip("multiprocessing")
    specs = GridAxes(strategy=("fednc_stream", "fedavg"),
                     straggler=("exponential",), population=(300,),
                     clients_per_round=8, rounds=2).expand()
    path = tmp_path / "TRACE_grid.json"
    results = run_grid(specs, jobs=2, trace_path=path)
    assert len(results) == 2
    for entry in results.values():
        assert entry["per_stage"].get("sim.round", 0.0) > 0.0
    events = obs.load_trace(path)
    assert obs.validate_trace(events) == []
    pids = {e["pid"] for e in events}
    assert len(pids) == 2, f"expected one pid lane per worker: {pids}"
