"""Paper CNN (§IV-A.1): shapes, BN state, learnability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_image_dataset
from repro.data.synthetic import batches
from repro.models.cnn import apply_cnn, cnn_accuracy, cnn_loss, init_cnn
from repro.optim import adam, apply_updates


def test_cnn_shapes():
    params = init_cnn(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 32, 32, 3))
    logits, new_params = apply_cnn(params, x, train=True)
    assert logits.shape == (4, 10)
    # BN stats updated in train mode
    assert not np.allclose(np.asarray(new_params["conv0"]["bn_var"]),
                           np.asarray(params["conv0"]["bn_var"]))


def test_cnn_learns_synthetic_classes():
    ds = make_image_dataset(512, seed=0)
    params = init_cnn(jax.random.PRNGKey(1))
    opt = adam(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        (loss, new_params), grads = jax.value_and_grad(
            cnn_loss, has_aux=True)(params, (x, y))
        upd, state = opt.update(grads, state, params)
        params = apply_updates(new_params, upd)
        return params, state, loss

    losses = []
    for x, y in batches(ds, 64, epochs=4, seed=1):
        params, state, loss = step(params, state, jnp.asarray(x),
                                   jnp.asarray(y))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::8]
    acc = cnn_accuracy(params, ds.images, ds.labels)
    assert acc > 0.5   # 10-class chance is 0.1
