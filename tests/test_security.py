"""Prop. 2 error bound, Table-I error probabilities, eavesdropper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import security
from repro.core.channel import Eavesdropper
from repro.core.rlnc import EncodedBatch, random_coding_matrix


def test_bound_matches_paper_table1():
    """Paper Table I: p_e for (s,η) = (1,1), (4,1), (8,1), (8,100)."""
    assert security.error_probability_bound(1, 1) == pytest.approx(0.5)
    assert security.error_probability_bound(4, 1) == pytest.approx(0.0625)
    assert security.error_probability_bound(8, 1) == pytest.approx(
        0.0039, abs=1e-4)
    assert security.error_probability_bound(8, 100) == pytest.approx(
        0.3239, abs=2e-3)


def test_bound_monotonicity():
    # decreasing in s, increasing in eta
    for eta in (1, 10):
        vals = [security.error_probability_bound(s, eta)
                for s in (1, 2, 4, 8)]
        assert vals == sorted(vals, reverse=True)
    for s in (1, 8):
        vals = [security.error_probability_bound(s, e)
                for e in (1, 10, 100)]
        assert vals == sorted(vals)


def test_singular_probability_close_to_bound_for_eta1():
    """For η=1 (one coding stage) the exact K×K singularity probability
    is upper-bounded by ~ sum of the geometric tail and is close to
    1/2^s for large s."""
    p = security.singular_probability_uniform(K=10, s=8)
    assert 0.003 < p < 0.005


@pytest.mark.slow
def test_simulated_error_rate_within_bound():
    for s, eta in [(4, 1), (8, 1)]:
        rate = security.simulate_error_probability(
            K=6, s=s, eta=eta, trials=150, seed=0)
        bound = security.error_probability_bound(s, eta)
        # simulation must not exceed the bound by more than MC noise
        assert rate <= bound + 3 * np.sqrt(bound / 150 + 1e-4)


def test_eavesdropper_partial_interception_leaks_nothing():
    s, K = 8, 8
    key = jax.random.PRNGKey(0)
    A = random_coding_matrix(key, K, K, s)
    batch = EncodedBatch(A=A, C=jnp.zeros((K, 4), jnp.uint8))
    ev = Eavesdropper(p_intercept=0.3, seed=1)
    res = ev.attack_encoded(batch, s)
    if res["rank"] < K:
        assert res["full_leak"] is False
        assert res["partial_leak_packets"] == 0
    # FedAvg baseline leaks every intercepted packet
    plain = ev.attack_plain(K)
    assert plain["partial_leak_packets"] == plain["intercepted"]


def test_eavesdropper_leak_probability_formula():
    # must capture all K tuples: p^K factor dominates
    p = security.eavesdropper_full_leak_probability(K=10, p_intercept=0.5)
    assert p < 0.5**10 + 1e-9
    assert security.fedavg_expected_leak(10, 0.5) == 5.0


def test_full_rank_probability_rank_wall_and_limits():
    """The rank-K wall in closed form: zero below K tuples, product
    form at and above, → 1 as redundancy grows."""
    K, s = 8, 8
    for n in range(K):
        assert security.full_rank_probability(n, K, s) == 0.0
    q = float(2**s)
    # n == K: the classic prod_{i=1}^{K} (1 - q^-i)
    exact = float(np.prod([1 - q**-(K - i) for i in range(K)]))
    assert security.full_rank_probability(K, K, s) == pytest.approx(exact)
    # complement of singular probability at n == K
    assert security.full_rank_probability(K, K, s) == pytest.approx(
        1.0 - security.singular_probability_uniform(K, s))
    # monotone in n, approaching 1
    vals = [security.full_rank_probability(n, K, s)
            for n in range(K, K + 6)]
    assert vals == sorted(vals)
    assert vals[-1] > 1 - 1e-9


def test_full_rank_probability_matches_monte_carlo():
    # rank via EavesdropperView: fixed (n, K) ingest shape, so the
    # jitted scan compiles once across all trials
    from repro.adversary import EavesdropperView
    from repro.core.gf import get_field
    K, n, s, trials = 4, 5, 4, 400
    f = get_field(s)
    hits = 0
    for t in range(trials):
        view = EavesdropperView(K=K, s=s)
        view.observe(f.random_elements(jax.random.PRNGKey(t), (n, K)))
        hits += int(view.full_leak)
    closed = security.full_rank_probability(n, K, s)
    tol = 5 * np.sqrt(closed * (1 - closed) / trials)
    assert abs(hits / trials - closed) < tol


def test_eavesdropper_leak_probability_mixture():
    """The binomial-mixture form: consistent with its n == K special
    case, monotone in every argument the right way."""
    K, s = 8, 8
    # at n == K the mixture collapses to p^K * full_rank(K, K)
    assert security.eavesdropper_leak_probability(
        K, K, 0.9, s) == pytest.approx(
        security.eavesdropper_full_leak_probability(K, 0.9, s))
    # degenerate interception probabilities
    assert security.eavesdropper_leak_probability(12, K, 0.0, s) == 0.0
    assert security.eavesdropper_leak_probability(
        12, K, 1.0, s) == pytest.approx(
        security.full_rank_probability(12, K, s))
    # monotone: more transmissions, higher p, fewer unknowns all help
    for p in (0.5, 0.9):
        vals = [security.eavesdropper_leak_probability(n, K, p, s)
                for n in range(K, K + 8)]
        assert vals == sorted(vals)
    vals = [security.eavesdropper_leak_probability(12, K, p, s)
            for p in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert vals == sorted(vals)
    # collusion: c insiders leave K - c unknowns -> strictly easier
    assert (security.eavesdropper_leak_probability(12, K - 3, 0.5, s)
            > security.eavesdropper_leak_probability(12, K, 0.5, s))


@pytest.mark.slow
def test_eavesdropper_leak_probability_matches_monte_carlo():
    from repro.adversary import EavesdropperView
    from repro.core.gf import get_field
    K, n, p, s, trials = 4, 6, 0.7, 4, 500
    f = get_field(s)
    hits = 0
    for t in range(trials):
        view = EavesdropperView(K=K, s=s, seed=t, p_intercept=p)
        view.intercept(f.random_elements(jax.random.PRNGKey(t), (n, K)))
        hits += int(view.full_leak)
    closed = security.eavesdropper_leak_probability(n, K, p, s)
    tol = 5 * np.sqrt(closed * (1 - closed) / trials)
    assert abs(hits / trials - closed) < tol
