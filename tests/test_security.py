"""Prop. 2 error bound, Table-I error probabilities, eavesdropper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import security
from repro.core.channel import Eavesdropper
from repro.core.rlnc import EncodedBatch, random_coding_matrix


def test_bound_matches_paper_table1():
    """Paper Table I: p_e for (s,η) = (1,1), (4,1), (8,1), (8,100)."""
    assert security.error_probability_bound(1, 1) == pytest.approx(0.5)
    assert security.error_probability_bound(4, 1) == pytest.approx(0.0625)
    assert security.error_probability_bound(8, 1) == pytest.approx(
        0.0039, abs=1e-4)
    assert security.error_probability_bound(8, 100) == pytest.approx(
        0.3239, abs=2e-3)


def test_bound_monotonicity():
    # decreasing in s, increasing in eta
    for eta in (1, 10):
        vals = [security.error_probability_bound(s, eta)
                for s in (1, 2, 4, 8)]
        assert vals == sorted(vals, reverse=True)
    for s in (1, 8):
        vals = [security.error_probability_bound(s, e)
                for e in (1, 10, 100)]
        assert vals == sorted(vals)


def test_singular_probability_close_to_bound_for_eta1():
    """For η=1 (one coding stage) the exact K×K singularity probability
    is upper-bounded by ~ sum of the geometric tail and is close to
    1/2^s for large s."""
    p = security.singular_probability_uniform(K=10, s=8)
    assert 0.003 < p < 0.005


@pytest.mark.slow
def test_simulated_error_rate_within_bound():
    for s, eta in [(4, 1), (8, 1)]:
        rate = security.simulate_error_probability(
            K=6, s=s, eta=eta, trials=150, seed=0)
        bound = security.error_probability_bound(s, eta)
        # simulation must not exceed the bound by more than MC noise
        assert rate <= bound + 3 * np.sqrt(bound / 150 + 1e-4)


def test_eavesdropper_partial_interception_leaks_nothing():
    s, K = 8, 8
    key = jax.random.PRNGKey(0)
    A = random_coding_matrix(key, K, K, s)
    batch = EncodedBatch(A=A, C=jnp.zeros((K, 4), jnp.uint8))
    ev = Eavesdropper(p_intercept=0.3, seed=1)
    res = ev.attack_encoded(batch, s)
    if res["rank"] < K:
        assert res["full_leak"] is False
        assert res["partial_leak_packets"] == 0
    # FedAvg baseline leaks every intercepted packet
    plain = ev.attack_plain(K)
    assert plain["partial_leak_packets"] == plain["intercepted"]


def test_eavesdropper_leak_probability_formula():
    # must capture all K tuples: p^K factor dominates
    p = security.eavesdropper_full_leak_probability(K=10, p_intercept=0.5)
    assert p < 0.5**10 + 1e-9
    assert security.fedavg_expected_leak(10, 0.5) == 5.0
