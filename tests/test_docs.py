"""Docs stay true (fast tier): scripts/check_docs.py must pass.

The checker executes every fenced ```python block in README.md,
docs/engine.md, docs/simulator.md, and benchmarks/README.md,
verifies the documented
kernel-registry names against `repro.engine.available_kernels()`, and
diffs the README throughput table against BENCH_kernels.json.  Run in
a subprocess so its registry mutations (the register_kernel example)
and doc-snippet namespaces never leak into this test process.
"""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_check_docs_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True, timeout=600, cwd=ROOT, env=env)
    assert proc.returncode == 0, (
        f"docs drifted from the code:\n{proc.stderr}\n{proc.stdout}")
