"""Docs stay true (fast tier): scripts/check_docs.py must pass.

The checker executes every fenced ```python block in README.md,
docs/engine.md, docs/simulator.md, and benchmarks/README.md,
verifies the documented
kernel-registry names against `repro.engine.available_kernels()`, and
diffs the README throughput table against BENCH_kernels.json.  Run in
a subprocess so its registry mutations (the register_kernel example)
and doc-snippet namespaces never leak into this test process.
"""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_check_docs_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True, timeout=600, cwd=ROOT, env=env)
    assert proc.returncode == 0, (
        f"docs drifted from the code:\n{proc.stderr}\n{proc.stdout}")


def test_check_docs_catches_registry_name_drift(tmp_path):
    """A kernel documented under the markers but absent from the live
    registry (or vice versa) is a failure, not a warning — this is the
    check that keeps README/docs tables honest when the registry
    grows (e.g. the seeded family)."""
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    doctored = tmp_path / "README.md"
    text = (ROOT / "README.md").read_text()
    doctored.write_text(text.replace("`jnp_packed_seeded`",
                                     "`jnp_packed_reseeded`", 1))
    errors = check_docs.check_kernel_names(doctored)
    assert errors and "registry" in errors[0]
    # the real docs pass through the same function
    assert check_docs.check_kernel_names(ROOT / "README.md") == []
