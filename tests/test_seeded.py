"""Seeded coding vectors: bit-exactness vs the materialized oracle.

The seeded kernel family regenerates RLNC coefficient rows from 4-byte
Threefry seeds *inside* the GF matmul (`repro.core.seeds`).  The whole
contract is bit-exactness — same seed ⇒ byte-identical row on every
path — so these tests pin:

* the Threefry-2x32-20 core against the published Random123
  known-answer vectors,
* `expand_rows` layout properties (determinism, s-bit masking, the
  counter-stream prefix property),
* all three seeded registry kernels against
  ``gf_matmul_ref(expand_rows(seeds), P)``,
* `StreamDecoder` seeded ingestion against materialized ingestion over
  random K / block size / arrival order / duplicated (dependent) seeds
  — hypothesis-driven when available, deterministic sweep otherwise,
* `CodingEngine` seeded encode / recode-composition / round semantics,
* the seed-addressed wire format and the `examples/seeded_overhead.py`
  walkthrough (fast-tier runnable, and its numbers must be honest).
"""
import pathlib
import runpy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import seeds as seedlib
from repro.core.channel import ErasureChannel, MultiHopChannel
from repro.core.gf import get_field
from repro.core.packets import (pack_seed_packet, packet_wire_bytes,
                                unpack_seed_packet)
from repro.core.rlnc import EncodedBatch, SeededBatch
from repro.engine import (CodingEngine, EngineConfig, StreamDecoder,
                          is_seeded_kernel, materialized_kernel_name,
                          resolve_kernel, seeded_kernel_name)
from repro.kernels import ref

ROOT = pathlib.Path(__file__).resolve().parent.parent
SEEDED_KERNELS = ("jnp_seeded", "jnp_packed_seeded",
                  "pallas_packed_seeded")


# ---------------------------------------------------------------------------
# the PRNG core: Random123 known-answer vectors
# ---------------------------------------------------------------------------

# (key0, key1, ctr0, ctr1) -> (out0, out1), Threefry-2x32 20 rounds,
# from the Random123 distribution's kat_vectors file.
THREEFRY_KAT = [
    ((0x00000000, 0x00000000, 0x00000000, 0x00000000),
     (0x6B200159, 0x99BA4EFE)),
    ((0xFFFFFFFF, 0xFFFFFFFF, 0xFFFFFFFF, 0xFFFFFFFF),
     (0x1CB996FC, 0xBB002BE7)),
    ((0x13198A2E, 0x03707344, 0x243F6A88, 0x85A308D3),
     (0xC4923A9C, 0x483DF7A0)),
]


@pytest.mark.parametrize("kat", THREEFRY_KAT,
                         ids=["zeros", "ones", "pi"])
def test_threefry_known_answer(kat):
    (k0, k1, x0, x1), want = kat
    y0, y1 = seedlib.threefry2x32(k0, k1, x0, x1)
    assert (int(y0), int(y1)) == want


def test_threefry_broadcasts():
    """Vectorized evaluation == element-wise evaluation."""
    ks = jnp.array([0, 0xFFFFFFFF, 7, 9], dtype=jnp.uint32)
    xs = jnp.array([0, 0xFFFFFFFF, 1, 2], dtype=jnp.uint32)
    y0, y1 = seedlib.threefry2x32(ks, seedlib.KEY_SALT, xs, 0)
    for i in range(4):
        a0, a1 = seedlib.threefry2x32(ks[i], seedlib.KEY_SALT,
                                      xs[i], 0)
        assert int(y0[i]) == int(a0) and int(y1[i]) == int(a1)


# ---------------------------------------------------------------------------
# row expansion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 4, 8])
def test_expand_rows_masks_to_field(s):
    A = seedlib.expand_rows(jnp.arange(16, dtype=jnp.uint32), K=33, s=s)
    assert A.shape == (16, 33) and A.dtype == jnp.uint8
    assert int(A.max()) < (1 << s)


def test_expand_rows_deterministic_and_distinct():
    seeds = jnp.array([5, 5, 6], dtype=jnp.uint32)
    A = seedlib.expand_rows(seeds, K=40)
    B = seedlib.expand_rows(seeds, K=40)
    np.testing.assert_array_equal(np.asarray(A), np.asarray(B))
    assert (A[0] == A[1]).all()          # same seed, same row
    assert not (A[0] == A[2]).all()      # different seed


def test_expand_rows_counter_stream_prefix():
    """Coefficient j depends only on (seed, j): widening K extends the
    row without rewriting its prefix — the property that lets encoder
    and decoder disagree on padding but never on coefficients."""
    seeds = jnp.array([1, 2, 3], dtype=jnp.uint32)
    short = seedlib.expand_rows(seeds, K=5)
    long = seedlib.expand_rows(seeds, K=19)
    np.testing.assert_array_equal(np.asarray(short),
                                  np.asarray(long[:, :5]))


def test_expand_rows_matches_word_layout():
    """Coefficient j == byte j%4 of Threefry word j//4, masked."""
    seed = jnp.uint32(0xDEADBEEF)
    row = np.asarray(seedlib.expand_rows(seed[None], K=8, s=8)[0])
    for j in range(8):
        w0, _ = seedlib.threefry2x32(seed, seedlib.KEY_SALT,
                                     jnp.uint32(j // 4), 0)
        assert row[j] == (int(w0) >> (8 * (j % 4))) & 0xFF


# ---------------------------------------------------------------------------
# the three seeded kernels vs the materialized oracle
# ---------------------------------------------------------------------------

SHAPES = [(1, 1, 1), (4, 3, 17), (3, 9, 2049)]   # incl. padding paths


@pytest.mark.parametrize("s", [1, 2, 4, 8])
@pytest.mark.parametrize("name", SEEDED_KERNELS)
@pytest.mark.parametrize("n,K,L", SHAPES)
def test_seeded_kernel_matches_oracle(name, s, n, K, L):
    key = jax.random.PRNGKey(n * 1000 + K * 10 + s)
    k1, k2 = jax.random.split(key)
    seeds = seedlib.draw_seeds(k1, n)
    P = get_field(s).random_elements(k2, (K, L))
    _, fn = resolve_kernel(name)
    got = fn(seeds, P, s=s)
    A = seedlib.expand_rows(seeds, K, s)
    want = ref.gf_matmul_ref(A, P, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_registry_sibling_names():
    for name in SEEDED_KERNELS:
        assert is_seeded_kernel(name)
        mat = materialized_kernel_name(name)
        assert not is_seeded_kernel(mat)
        assert seeded_kernel_name(mat) == name


# ---------------------------------------------------------------------------
# StreamDecoder: seeded ingestion == materialized ingestion
# ---------------------------------------------------------------------------

def _seeded_stream_case(s, K, g, L, case_seed, dup):
    """Seeded and materialized decoders fed the same tuples (arrival
    order shuffled, optionally with duplicated seeds — dependent rows)
    must report identical rank trajectories and identical bytes."""
    rng = np.random.default_rng(case_seed)
    seeds = rng.integers(0, 1 << 32, size=g, dtype=np.uint32)
    if dup and g >= 2:                    # force dependent rows
        seeds[rng.integers(0, g, size=max(1, g // 3))] = seeds[0]
    order = rng.permutation(g)
    seeds = jnp.asarray(seeds[order])
    f = get_field(s)
    A = seedlib.expand_rows(seeds, K, s)
    P = f.random_elements(jax.random.PRNGKey(case_seed), (K, L))
    C = f.matmul(A, P)

    dec_s = StreamDecoder(K=K, L=L, s=s)
    dec_m = StreamDecoder(K=K, L=L, s=s)
    ranks_s = dec_s.ingest_seeded(seeds, C)
    ranks_m = dec_m.ingest(A, C)
    np.testing.assert_array_equal(ranks_s, ranks_m)
    assert dec_s.decoded_at == dec_m.decoded_at
    ok_s, P_s = dec_s.decode()
    ok_m, P_m = dec_m.decode()
    assert ok_s == ok_m
    if ok_s:
        np.testing.assert_array_equal(np.asarray(P_s), np.asarray(P_m))
        np.testing.assert_array_equal(np.asarray(P_s), np.asarray(P))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(s=st.sampled_from([1, 2, 4, 8]), K=st.integers(2, 6),
           g=st.integers(1, 10), L=st.integers(1, 24),
           case_seed=st.integers(0, 2**30), dup=st.booleans())
    def test_seeded_stream_property(s, K, g, L, case_seed, dup):
        _seeded_stream_case(s, K, g, L, case_seed, dup)
else:
    @pytest.mark.parametrize("s,K,g,L,case_seed,dup", [
        (8, 5, 8, 16, 0, False), (8, 4, 9, 7, 1, True),
        (4, 6, 10, 9, 2, False), (2, 3, 6, 24, 3, True),
        (1, 4, 8, 7, 4, True), (8, 2, 1, 1, 5, False),
    ])
    def test_seeded_stream_cases(s, K, g, L, case_seed, dup):
        """Deterministic sweep standing in when hypothesis is absent
        (pip install -r requirements-dev.txt for the full search)."""
        _seeded_stream_case(s, K, g, L, case_seed, dup)


def test_stream_scalar_seed_push():
    """push() accepts a scalar uint32 seed in place of a (K,) row."""
    s, K, L = 8, 4, 10
    f = get_field(s)
    seeds = seedlib.draw_seeds(jax.random.PRNGKey(1), 6)
    A = seedlib.expand_rows(seeds, K, s)
    P = f.random_elements(jax.random.PRNGKey(2), (K, L))
    C = f.matmul(A, P)
    dec_s = StreamDecoder(K=K, L=L, s=s)
    dec_m = StreamDecoder(K=K, L=L, s=s)
    for g in range(6):
        assert dec_s.push(seeds[g], C[g]) == dec_m.push(A[g], C[g])
    assert dec_s.decoded_at == dec_m.decoded_at
    np.testing.assert_array_equal(np.asarray(dec_s.decode()[1]),
                                  np.asarray(P))


def test_stream_ingest_autodetects_seed_block():
    """A 1-D uint32 block through plain ingest() routes to the seeded
    path — callers never branch on wire format."""
    s, K, L = 8, 3, 5
    seeds = seedlib.draw_seeds(jax.random.PRNGKey(3), 5)
    f = get_field(s)
    A = seedlib.expand_rows(seeds, K, s)
    P = f.random_elements(jax.random.PRNGKey(4), (K, L))
    C = f.matmul(A, P)
    via_ingest = StreamDecoder(K=K, L=L, s=s).ingest(seeds, C)
    via_seeded = StreamDecoder(K=K, L=L, s=s).ingest_seeded(seeds, C)
    np.testing.assert_array_equal(via_ingest, via_seeded)


def test_stream_col_mask_equals_masked_rows():
    """col_mask dropout == zeroing the dead sources' coefficients in
    the materialized rows (the simulator's semantics)."""
    s, K, g, L = 8, 6, 12, 8
    rng = np.random.default_rng(7)
    seeds = jnp.asarray(rng.integers(0, 1 << 32, g, dtype=np.uint32))
    live = np.ones(K, bool)
    live[[1, 4]] = False
    f = get_field(s)
    A = np.asarray(seedlib.expand_rows(seeds, K, s)).copy()
    P = f.random_elements(jax.random.PRNGKey(5), (K, L))
    C = f.matmul(jnp.asarray(A), P)      # payloads from the full rows
    A[:, ~live] = 0
    dec_s = StreamDecoder(K=K, L=L, s=s)
    dec_m = StreamDecoder(K=K, L=L, s=s)
    ranks_s = dec_s.ingest_seeded(seeds, C, col_mask=jnp.asarray(live))
    ranks_m = dec_m.ingest(jnp.asarray(A), C)
    np.testing.assert_array_equal(ranks_s, ranks_m)
    np.testing.assert_array_equal(np.asarray(dec_s.basis()),
                                  np.asarray(dec_m.basis()))


# ---------------------------------------------------------------------------
# CodingEngine: seeded encode / recode / round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SEEDED_KERNELS)
def test_engine_encode_seeded_matches_materialized(name):
    s, K, L = 8, 6, 700
    eng = CodingEngine(EngineConfig(s=s, kernel=name, chunk_l=256))
    mat = CodingEngine(EngineConfig(
        s=s, kernel=materialized_kernel_name(name), chunk_l=256))
    P = get_field(s).random_elements(jax.random.PRNGKey(0), (K, L))
    seeds = eng.coding_seeds(jax.random.PRNGKey(1), K + 2)
    sb = eng.encode_seeded(P, seeds)
    assert isinstance(sb, SeededBatch) and sb.K == K
    mb = mat.encode(P, eng.expand_seeds(seeds, K))
    np.testing.assert_array_equal(np.asarray(sb.C), np.asarray(mb.C))
    # any engine consumes either wire format: the materialized engine
    # fed the seed vector produces the identical batch
    sb2 = mat.encode(P, seeds)
    assert isinstance(sb2, SeededBatch)
    np.testing.assert_array_equal(np.asarray(sb2.C), np.asarray(sb.C))


def test_engine_recode_composes_seeded_batch():
    """Prop. 2 at a relay holding seed-addressed tuples: recode output
    is materialized (R·A has no 4-byte seed) and bit-identical to
    recoding the expanded batch."""
    s, K, n, L = 8, 5, 7, 64
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed_seeded"))
    P = get_field(s).random_elements(jax.random.PRNGKey(2), (K, L))
    sb = eng.encode_seeded(P, eng.coding_seeds(jax.random.PRNGKey(3), n))
    R = eng.field.random_elements(jax.random.PRNGKey(4), (6, n))
    relay = eng.recode_with(R, sb)
    assert isinstance(relay, EncodedBatch)
    oracle = eng.recode_with(R, sb.expand(s))
    np.testing.assert_array_equal(np.asarray(relay.A),
                                  np.asarray(oracle.A))
    np.testing.assert_array_equal(np.asarray(relay.C),
                                  np.asarray(oracle.C))
    ok, P_hat = eng.decode(relay)
    assert ok
    np.testing.assert_array_equal(np.asarray(P_hat), np.asarray(P))


@pytest.mark.parametrize("channel", [
    None,
    ErasureChannel(0.2, seed=11),
    MultiHopChannel(2, seed=12),
], ids=["ideal", "erasure", "multihop"])
def test_engine_seeded_round_decodes(channel):
    s, K, L = 8, 6, 300
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed_seeded",
                                    chunk_l=128, extra_tuples=8))
    P = get_field(s).random_elements(jax.random.PRNGKey(6), (K, L))
    out = eng.round(P, jax.random.PRNGKey(7), channel=channel)
    assert out.ok
    np.testing.assert_array_equal(np.asarray(out.packets),
                                  np.asarray(P))


def test_coding_seeds_rejects_structured_rows():
    """Systematic / sparse rows are not derivable from a 4-byte seed."""
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="uniform RLNC"):
        CodingEngine(EngineConfig(s=8, systematic=True)
                     ).coding_seeds(key, 4)
    with pytest.raises(ValueError, match="uniform RLNC"):
        CodingEngine(EngineConfig(s=8, coding_density=0.5)
                     ).coding_seeds(key, 4)


# ---------------------------------------------------------------------------
# the wire format + the example
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [4, 8])
def test_seed_packet_roundtrip(s):
    payload = get_field(s).random_elements(jax.random.PRNGKey(8), (40,))
    seed = jnp.uint32(0x01234567)
    buf = pack_seed_packet(seed, payload, s)
    assert buf.nbytes == packet_wire_bytes(0, 40, s, seeded=True)
    got_seed, got_payload = unpack_seed_packet(buf, s)
    assert int(got_seed) == 0x01234567
    np.testing.assert_array_equal(np.asarray(got_payload[:40]),
                                  np.asarray(payload))


def test_packet_wire_bytes_headline_numbers():
    for K in (32, 128, 512):
        mat = packet_wire_bytes(K, 4096, 8, seeded=False)
        sed = packet_wire_bytes(K, 4096, 8, seeded=True)
        assert mat == K + 4096 and sed == 4 + 4096
    assert packet_wire_bytes(128, 4096, 8, seeded=True) == 4100


def test_seeded_overhead_example_runs():
    """examples/seeded_overhead.py is fast-tier runnable and its
    printed accounting is honest."""
    mod = runpy.run_path(
        str(ROOT / "examples" / "seeded_overhead.py"))
    stats = mod["main"]()
    assert stats["K"] == 128
    assert stats["bytes_per_packet_seeded"] == packet_wire_bytes(
        128, stats["L"], 8, seeded=True)
    assert stats["round_ratio"] < 1.0
