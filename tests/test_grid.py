"""The scenario-grid runner: spec expansion, executors, artifacts,
and the compute-coupled arrival schedule (ROADMAP closure items)."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core.channel import ArrivalSchedule
from repro.grid import (GridAxes, grid_document, markdown_report,
                        run_grid, run_scenario, scenario_seed)
from repro.grid.spec import with_rounds

# ---------------------------------------------------------------------------
# Spec expansion
# ---------------------------------------------------------------------------


def test_expand_is_cartesian_and_deduplicated():
    g = GridAxes(strategy=("fednc_stream", "fedavg"),
                 straggler=("exponential", "pareto"),
                 population=(1000, 2000),
                 kernel=("jnp", "jnp_packed"))
    specs = g.expand()
    # kernel never touches the simulator strategies, so the kernel
    # axis collapses instead of duplicating every sim cell
    assert len(specs) == 2 * 2 * 2
    assert len({s.name for s in specs}) == len(specs)
    assert all(s.kernel == "-" for s in specs)


def test_hier_normalization_collapses_stream_axes():
    g = GridAxes(strategy=("hier:4",), straggler=("pareto",),
                 delay_spread=(0.0, 5.0), kernel=("jnp",),
                 clients_per_round=8)
    specs = g.expand()
    assert len(specs) == 1
    assert specs[0].num_edges == 4
    assert specs[0].delay_spread == 0.0 and specs[0].straggler == "-"


def test_seeds_are_stable_under_grid_growth():
    small = GridAxes(strategy=("fedavg",), straggler=("pareto",))
    big = GridAxes(strategy=("fednc_stream", "fedavg", "hier:2"),
                   straggler=("constant", "exponential", "pareto"),
                   population=(10_000, 100_000))
    by_name = {s.name: s.seed for s in big.expand()}
    for s in small.expand():
        assert by_name[s.name] == s.seed == scenario_seed(s.name, 0)
    # different base seed -> different seeds, same names
    assert (scenario_seed("x", 0) != scenario_seed("x", 1))


def test_with_rounds_keeps_identity():
    s = GridAxes().expand()[0]
    s2 = with_rounds(s, 99)
    assert s2.rounds == 99 and s2.name == s.name and s2.seed == s.seed


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError):
        GridAxes(strategy=("bogus",)).expand()


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


def test_sim_scenario_reports_draw_ratio_fields():
    spec = GridAxes(strategy=("fednc_stream",),
                    straggler=("exponential",), population=(500,),
                    clients_per_round=16, rounds=4).expand()[0]
    entry = run_scenario(spec)
    assert entry["seed"] == spec.seed
    assert entry["axes"]["strategy"] == "fednc_stream"
    assert entry["fednc_decode_rate"] == 1.0
    assert entry["fednc_draws_mean"] >= 16
    assert entry["fedavg_draws_mean"] > entry["fednc_draws_mean"]
    assert np.isfinite(entry["draw_ratio"])
    # inflation is vs K·H(K); without reordering it hovers around 1
    assert 0.5 < entry["fedavg_inflation"] < 1.6


def test_sim_scenario_is_deterministic():
    spec = GridAxes(strategy=("fedavg",), straggler=("pareto",),
                    population=(500,), clients_per_round=16,
                    rounds=4).expand()[0]
    a, b = run_scenario(spec), run_scenario(spec)
    a.pop("wall_s"), b.pop("wall_s")
    a.pop("per_stage"), b.pop("per_stage")   # wall-clock, like wall_s
    assert a == b


def test_delay_reordering_inflates_fedavg():
    """The regime Prop. 1 cannot see: per-client reorder offsets push
    FedAvg's last coupon later, FedNC's rank law does not care."""
    mk = lambda d: GridAxes(strategy=("fedavg",),
                            straggler=("exponential",),
                            delay_spread=(d,), population=(2000,),
                            clients_per_round=32, rounds=25,
                            base_seed=5).expand()[0]
    base = run_scenario(mk(0.0))
    wide = run_scenario(mk(8.0))
    assert wide["fedavg_inflation"] > 1.2 * base["fedavg_inflation"]


def test_hier_scenario_decodes_through_kernel_axis():
    spec = GridAxes(strategy=("hier:2",), kernel=("jnp",),
                    clients_per_round=6, rounds=1).expand()[0]
    entry = run_scenario(spec)
    assert entry["decode_rate"] == 1.0
    assert entry["kernel_resolved"] == "jnp"
    assert entry["num_edges"] == 2


def test_run_grid_serial_matches_scenarios():
    specs = GridAxes(strategy=("fedavg",),
                     straggler=("exponential", "pareto"),
                     population=(500,), clients_per_round=16,
                     rounds=3).expand()
    results = run_grid(specs, jobs=1)
    assert list(results) == [s.name for s in specs]
    for s in specs:
        solo = run_scenario(s)
        solo.pop("wall_s"), solo.pop("per_stage")
        got = dict(results[s.name])
        got.pop("wall_s"), got.pop("per_stage")
        assert got == solo


@pytest.mark.slow
def test_run_grid_process_parallel_matches_serial():
    """jobs=2 spawns fresh-interpreter workers; results must be
    bit-identical to in-process execution."""
    specs = GridAxes(strategy=("fedavg", "fednc_stages"),
                     straggler=("pareto",), population=(500,),
                     clients_per_round=16, rounds=3).expand()
    serial = run_grid(specs, jobs=1)
    parallel = run_grid(specs, jobs=2)
    for name in serial:
        a, b = dict(serial[name]), dict(parallel[name])
        a.pop("wall_s"), b.pop("wall_s")
        a.pop("per_stage"), b.pop("per_stage")
        assert a == b


# ---------------------------------------------------------------------------
# Compute coupling (the ROADMAP item this PR closes)
# ---------------------------------------------------------------------------


def test_arrival_schedule_offset_by():
    sched = ArrivalSchedule(np.asarray([3.0, 1.0, 2.0]))
    shifted = sched.offset_by(np.asarray([0.0, 5.0, 0.0]))
    assert np.allclose(shifted.times, [3.0, 6.0, 2.0])
    # re-sorting is derived: the slow packet moved to the back
    assert shifted.order.tolist() == [2, 0, 1]
    with pytest.raises(ValueError):
        sched.offset_by(np.zeros(2))


def test_compute_model_modes():
    from repro.sim import ComputeModel, DistSpec
    rng = np.random.default_rng(0)
    t = ComputeModel(work=DistSpec("constant", 2.0, 0.0),
                     flops_per_second=4.0).times(rng, 5)
    assert np.allclose(t, 0.5)
    m = ComputeModel(measured_scale=10.0).times(
        rng, 3, measured_wall=np.asarray([0.1, 0.2, 0.3]))
    assert np.allclose(m, [1.0, 2.0, 3.0])
    with pytest.raises(ValueError):
        ComputeModel(measured_scale=1.0).times(rng, 3)


def test_async_strategy_compute_coupling_dominates():
    import jax.numpy as jnp

    from repro.core.fednc import FedNCConfig
    from repro.federation import AsyncFedNCStrategy, blind_box_schedule
    params = [{"w": jnp.arange(16, dtype=jnp.float32) * (k + 1)}
              for k in range(5)]
    strat = AsyncFedNCStrategy(config=FedNCConfig(s=8), budget=20,
                               schedule_fn=blind_box_schedule())
    w = np.full(5, 0.2, np.float32)
    rng = np.random.default_rng(3)
    ct = np.full(5, 2.5)
    res = strat.aggregate(params, w, params[0], rng, compute_times=ct)
    rep = res.report
    assert res.decoded
    # constant offsets shift every arrival by exactly 2.5: the decode
    # clock dominates the network-only clock by construction
    assert rep.sim_time > rep.sim_time_network > 0
    assert rep.sim_time == pytest.approx(rep.sim_time_network + 2.5)
    # and without coupling the two clocks coincide
    res2 = strat.aggregate(params, w, params[0],
                           np.random.default_rng(4))
    assert res2.report.sim_time == res2.report.sim_time_network


def test_blind_box_schedule_offset_by():
    from repro.federation import blind_box_schedule
    base = blind_box_schedule()(12, np.random.default_rng(7))
    # the strategy's coupling step: per-packet source attribution,
    # then offset_by with the sources' compute times
    offs = np.full(4, 3.0)[np.random.default_rng(7).integers(0, 4, 12)]
    coupled = base.offset_by(offs)
    assert np.allclose(np.asarray(coupled.times),
                       np.asarray(base.times) + 3.0)


def test_async_compute_scenario_dominates_network_only():
    spec = GridAxes(strategy=("async_compute",),
                    straggler=("lognormal",), clients_per_round=4,
                    rounds=2).expand()[0]
    entry = run_scenario(spec)
    assert entry["decode_rate"] == 1.0
    assert entry["compute_dominates"] is True
    assert entry["sim_time_mean"] > entry["sim_time_network_mean"]


# ---------------------------------------------------------------------------
# Artifact + report + CLI
# ---------------------------------------------------------------------------


def _tiny_doc():
    axes = GridAxes(strategy=("fedavg",), straggler=("exponential",),
                    population=(500,), clients_per_round=16, rounds=3)
    results = run_grid(axes.expand(), jobs=1)
    return grid_document(axes.config(), results)


def test_grid_document_passes_check_bench_schema():
    scripts = str(pathlib.Path(__file__).resolve().parent.parent
                  / "scripts")
    sys.path.insert(0, scripts)
    try:
        import check_bench
    finally:
        sys.path.remove(scripts)
    doc = _tiny_doc()
    assert check_bench.check_grid("tiny", doc) == []
    # a scenario without its seed must fail
    broken = json.loads(json.dumps(doc))
    next(iter(broken["scenarios"].values())).pop("seed")
    assert any("seed" in e for e in check_bench.check_grid("t", broken))
    # a sim scenario with null draw stats but no dropout must fail
    broken2 = json.loads(json.dumps(doc))
    next(iter(broken2["scenarios"].values()))["draw_ratio"] = None
    assert any("draw_ratio" in e
               for e in check_bench.check_grid("t", broken2))


def test_markdown_report_renders_scenarios():
    doc = _tiny_doc()
    md = markdown_report(doc)
    for name in doc["scenarios"]:
        assert f"`{name}`" in md
    assert "| scenario |" in md


@pytest.mark.slow
def test_cli_smoke_writes_valid_artifact(tmp_path):
    """`python -m repro.grid --smoke` end to end: the CI smoke job."""
    root = pathlib.Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-m", "repro.grid", "--smoke",
         "--outdir", str(tmp_path), "--jobs", "1"],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": str(root / "src")},
        cwd=str(root))
    assert proc.returncode == 0, proc.stderr
    doc = json.loads((tmp_path / "GRID_smoke.json").read_text())
    assert doc["schema"] == "fednc-grid-v1"
    assert len(doc["scenarios"]) == 6
    engine_cells = {k: v for k, v in doc["scenarios"].items()
                    if v["axes"]["strategy"] == "engine"}
    assert {v["axes"]["kernel"] for v in engine_cells.values()} == {
        "jnp_packed", "jnp_packed_seeded"}
    assert all(v["decode_rate"] == 1.0 for v in engine_cells.values())
    assert (tmp_path / "GRID_smoke.md").exists()
