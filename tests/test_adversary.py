"""The adversary models: rank wall, collusion, byzantine detection,
replayed seeds, and the grid's adversary axis (repro.adversary)."""
import pathlib
import runpy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adversary import (AdversarySpec, ByzantineChannel,
                             EavesdropperView, apply_tamper,
                             replayed_seed_batch, rounds_to_recovery,
                             tap_edges)
from repro.core.gf import get_field
from repro.core.security import eavesdropper_leak_probability
from repro.engine import CodingEngine, EngineConfig, StreamDecoder

ROOT = pathlib.Path(__file__).resolve().parent.parent
S = 8


# -- AdversarySpec: the grid axis value ----------------------------------

def test_spec_parses_every_kind():
    assert AdversarySpec.parse("none").none
    e = AdversarySpec.parse("eavesdrop:0.6")
    assert e.kind == "eavesdrop" and e.param == 0.6 and not e.none
    c = AdversarySpec.parse("collude:4")
    assert c.kind == "collude" and c.count == 4
    b = AdversarySpec.parse("byzantine:0.05")
    assert str(b) == "byzantine:0.05" and b.tag == "byzantine0.05"


@pytest.mark.parametrize("bad", ["eavesdrop:1.5", "collude:0",
                                 "collude:2.5", "byzantine:-0.1",
                                 "tamper:0.5", "eavesdrop"])
def test_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        AdversarySpec.parse(bad)


# -- EavesdropperView: the rank-K wall -----------------------------------

def test_view_rank_wall_and_residual_entropy():
    K = 8
    f = get_field(S)
    A = f.random_elements(jax.random.PRNGKey(0), (K + 4, K))
    view = EavesdropperView(K=K, s=S)
    view.observe(A[:K - 1])
    assert view.rank < K and not view.full_leak
    assert view.sources_recovered() == 0
    assert view.residual_entropy_bits(L=32) == (K - view.rank) * S * 32
    view.observe(A[K - 1:])
    assert view.full_leak and view.sources_recovered() == K
    assert view.residual_entropy_bits() == 0.0


def test_view_consumes_seed_headers():
    """The 4-byte wire format hides nothing from an attacker."""
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp_packed_seeded"))
    seeds = eng.coding_seeds(jax.random.PRNGKey(1), 10)
    view = EavesdropperView(K=8, s=S)
    view.observe(np.asarray(seeds))
    assert view.full_leak


def test_view_intercept_masks_to_fixed_shape():
    """Captured-count statistics are unchanged by the zero-row padding
    trick, and missed tuples really contribute nothing."""
    K, n = 8, 12
    f = get_field(S)
    A = f.random_elements(jax.random.PRNGKey(2), (n, K))
    view = EavesdropperView(K=K, s=S, seed=3, p_intercept=0.5)
    got = view.intercept(A)
    assert got == view.intercepted <= n
    assert view.rank <= got


def test_colluders_shrink_the_wall():
    K = 8
    view = EavesdropperView(K=K, s=S, colluders=(0, 1, 2))
    assert view.rank == 3 and view.sources_recovered() == 3
    # closed form: 3 insiders leave K-3 unknowns
    with_c = eavesdropper_leak_probability(12, K - 3, 0.5, s=S)
    without = eavesdropper_leak_probability(12, K, 0.5, s=S)
    assert with_c > without
    with pytest.raises(ValueError):
        EavesdropperView(K=4, colluders=(7,))


def test_edge_taps_structurally_capped():
    """Full rows of e < E edges span only their own clients' columns."""
    E, per = 3, 4
    K = E * per
    edges = [tuple(range(e * per, (e + 1) * per)) for e in range(E)]
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp"))
    n_out = [per + 1] * E
    for t in range(3):
        A = eng.multi_edge_coding_matrix(jax.random.PRNGKey(t), edges,
                                         K, n_out)
        for tapped in range(E):
            view = EavesdropperView(K=K, s=S)
            view.observe(tap_edges(A, edges, range(tapped),
                                   spare_per_edge=1))
            assert view.rank <= tapped * per < K
            assert not view.full_leak
        view = EavesdropperView(K=K, s=S)
        view.observe(tap_edges(A, edges, range(E), spare_per_edge=1))
        assert view.full_leak


def test_leak_rate_matches_closed_form():
    """Monte-Carlo full-leak rate through the view tracks the closed
    form (loose 5-sigma tolerance; bench_security tightens this)."""
    K, n, p, trials = 8, 12, 0.7, 120
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp"))
    leaks = 0
    for t in range(trials):
        A = eng.coding_matrix(jax.random.PRNGKey(t), n, K)
        view = EavesdropperView(K=K, s=S, seed=t, p_intercept=p)
        view.intercept(A)
        if view.intercepted < K:
            assert not view.full_leak    # the wall, per trial
        leaks += int(view.full_leak)
    closed = eavesdropper_leak_probability(n, K, p, s=S)
    tol = 5 * np.sqrt(closed * (1 - closed) / trials)
    assert abs(leaks / trials - closed) < tol


# -- ByzantineChannel: corruption, detection, recovery -------------------

def _payload(key, K=8, L=32):
    return jax.random.randint(key, (K, L), 0, 1 << S, dtype=jnp.uint8)


@pytest.mark.parametrize("mode", ["flip", "forge", "both"])
def test_fused_tamper_bit_exact_vs_stagewise(mode):
    """The fused RowTamper round must equal the stage-wise oracle for
    every corruption mode (same RNG stream, same decode algebra)."""
    P = _payload(jax.random.PRNGKey(0))
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp_packed",
                                    extra_tuples=4))
    for r in range(3):
        rk = jax.random.fold_in(jax.random.PRNGKey(1), r)
        fused = eng.round(P, rk, ByzantineChannel(0.3, seed=r,
                                                  mode=mode),
                          verify=True)
        # the stage-wise path consumes the same planned RNG stream
        chan = ByzantineChannel(0.3, seed=r, mode=mode)
        A = eng.coding_matrix(rk, 12, 8)
        batch = apply_tamper(eng.encode(P, A), chan.plan_transform(12, S),
                             S)
        ok, P_hat, verified = eng.decode_verified(batch)
        assert fused.ok == ok
        if ok:
            assert (fused.packets == P_hat).all()
            assert fused.verified == verified


def test_detection_and_no_silent_corruption():
    P = _payload(jax.random.PRNGKey(2))
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp_packed",
                                    extra_tuples=4))
    hostile = ByzantineChannel(rate=1.0, seed=5, mode="both")
    out = eng.round(P, jax.random.PRNGKey(3), hostile, verify=True)
    if out.ok:
        assert out.verified is False
    benign = ByzantineChannel(rate=0.0, seed=5)
    out = eng.round(P, jax.random.PRNGKey(3), benign, verify=True)
    assert out.ok and out.verified is True
    assert (out.packets == P).all()


def test_rounds_to_recovery_reaches_clean_decode():
    P = _payload(jax.random.PRNGKey(4))
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp_packed",
                                    extra_tuples=4))
    rec = rounds_to_recovery(eng, P, jax.random.PRNGKey(5),
                             ByzantineChannel(0.1, seed=6, mode="both"))
    assert rec["accepted"] and rec["correct"]
    assert rec["rounds"] >= 1
    assert rec["flagged"] + rec["rank_failures"] == rec["rounds"] - 1


def test_replayed_seeds_flagged_as_inconsistent():
    eng = CodingEngine(EngineConfig(s=S, kernel="jnp_packed_seeded"))
    P = _payload(jax.random.PRNGKey(6))
    seeds = eng.coding_seeds(jax.random.PRNGKey(7), 12)
    batch = eng.encode_seeded(P, seeds)
    attacked = replayed_seed_batch(batch, 4, s=S, seed=8)
    dec = StreamDecoder(K=8, L=32, s=S, detect=True)
    dec.ingest(attacked.seeds, attacked.C)
    assert dec.complete and dec.tampered and dec.inconsistent == 4
    assert dec.first_inconsistent_at > 8
    # honest stream: zero flags
    clean = StreamDecoder(K=8, L=32, s=S, detect=True)
    clean.ingest(batch.seeds, batch.C)
    assert clean.complete and not clean.tampered


# -- the grid axis -------------------------------------------------------

def test_grid_axis_normalization_and_stable_names():
    from repro.grid import GridAxes
    axes = GridAxes(strategy=("fednc_stream", "engine", "hier:2"),
                    straggler=("exponential",),
                    kernel=("jnp",),
                    adversary=("none", "eavesdrop:0.5",
                               "byzantine:0.1"),
                    clients_per_round=8, rounds=2, base_seed=1)
    names = [s.name for s in axes.expand()]
    # sim cells collapse the adversary axis entirely (no coded payload
    # crosses a channel); no pre-existing name gains a suffix
    assert names.count("fednc_stream-exponential-d0-p0-n10000-k-") == 1
    assert sum("fednc_stream" in n for n in names) == 1
    # engine cells carry every adversary; hier keeps only eavesdrop
    assert "engine---d0-p0-n8-kjnp-aeavesdrop0.5" in names
    assert "engine---d0-p0-n8-kjnp-abyzantine0.1" in names
    assert "hier2---d0-p0-n8-kjnp-aeavesdrop0.5" in names
    assert not any("hier2" in n and "byzantine" in n for n in names)
    specs = {s.name: s for s in axes.expand()}
    assert specs["engine---d0-p0-n8-kjnp"].adversary == "none"


def test_grid_engine_eavesdrop_cell_metrics():
    from repro.grid import GridAxes, run_scenario
    axes = GridAxes(strategy=("engine",), straggler=("exponential",),
                    kernel=("jnp",), adversary=("eavesdrop:0.6",),
                    clients_per_round=8, rounds=2, base_seed=2)
    spec = axes.expand()[0]
    entry = run_scenario(spec)
    assert entry["decode_rate"] == 1.0
    assert 0 <= entry["eavesdrop_rank_mean"] <= 8 + 0.0
    assert 0.0 <= entry["full_leak_rate"] <= 1.0
    assert 0.0 <= entry["leak_probability_closed_form"] <= 1.0
    assert entry["residual_entropy_bits_mean"] >= 0.0


def test_grid_engine_byzantine_cell_metrics():
    from repro.grid import GridAxes, run_scenario
    axes = GridAxes(strategy=("engine",), straggler=("exponential",),
                    kernel=("jnp",), adversary=("byzantine:0.2",),
                    clients_per_round=8, rounds=2, base_seed=3)
    entry = run_scenario(axes.expand()[0])
    assert entry["undetected_bad_decodes"] == 0
    assert 0.0 <= entry["detection_rate"] <= 1.0
    assert entry["rounds_to_recovery_mean"] >= 1.0
    assert entry["corrupted_round_rate"] >= 0.0


@pytest.mark.slow
def test_grid_hier_eavesdrop_cell_rank_wall():
    from repro.grid import GridAxes, run_scenario
    axes = GridAxes(strategy=("hier:2",), kernel=("jnp",),
                    adversary=("eavesdrop:0.5",),
                    clients_per_round=8, rounds=2, base_seed=4)
    entry = run_scenario(axes.expand()[0])
    assert entry["rank_wall_holds"] is True
    assert entry["tapped_edges_mean"] >= 1.0


def test_eavesdropper_rank_example_runs():
    ns = runpy.run_path(str(ROOT / "examples" / "eavesdropper_rank.py"),
                        run_name="not_main")
    out = ns["main"]()
    below = [r for r in out["edge_taps"] if r["tapped"] < ns["EDGES"]]
    assert all(r["full_leak_rate"] == 0.0 for r in below)
    assert out["edge_taps"][-1]["full_leak_rate"] == 1.0
