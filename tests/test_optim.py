"""Native optimizers: convergence + analytic checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam, adamw, apply_updates, constant,
                         cosine_decay, linear_warmup_cosine, momentum, sgd)
from repro.optim.base import clip_by_global_norm, global_norm


def _quadratic_min(opt, steps=300):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"x": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


def test_sgd_converges():
    assert _quadratic_min(sgd(0.1)) < 1e-6


def test_momentum_converges():
    assert _quadratic_min(momentum(0.05, 0.9)) < 1e-6


def test_adam_converges():
    assert _quadratic_min(adam(0.1)) < 1e-4


def test_adamw_decays_weights():
    opt = adamw(0.01, weight_decay=0.5)
    params = {"x": jnp.ones(4)}
    state = opt.init(params)
    zero_g = {"x": jnp.zeros(4)}
    upd, state = opt.update(zero_g, state, params)
    new = apply_updates(params, upd)
    assert float(new["x"][0]) < 1.0   # pure decay shrinks weights


def test_adam_first_step_is_lr_sized():
    """With bias correction the first Adam step ≈ lr·sign(grad)."""
    opt = adam(0.1)
    params = {"x": jnp.zeros(2)}
    state = opt.init(params)
    g = {"x": jnp.asarray([1.0, -2.0])}
    upd, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd["x"]),
                               [-0.1, 0.1], rtol=1e-4)


def test_bf16_state_dtype():
    opt = adam(0.1, state_dtype=jnp.bfloat16)
    params = {"x": jnp.zeros(3, jnp.float32)}
    state = opt.init(params)
    assert state.slots["m"]["x"].dtype == jnp.bfloat16


def test_schedules():
    c = constant(0.5)
    assert float(c(jnp.int32(10))) == 0.5
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.int32(0))) == pytest.approx(1.0)
    assert float(cd(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    wc = linear_warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.int32(5))) == pytest.approx(0.5)
    assert float(wc(jnp.int32(10))) == pytest.approx(1.0)


def test_clip_by_global_norm():
    tree = {"a": jnp.full(4, 10.0)}
    clipped = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
