"""Pallas GF kernels vs the pure-jnp table oracle: shape/dtype sweep.

The kernel computes GF products via carry-less multiply + polynomial
reduction; the oracle uses log/antilog tables — two independent
formulations, so equality is strong evidence of correctness.
Kernels run in interpret mode (CPU container); on TPU the same
pallas_call executes compiled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gf import get_field
from repro.kernels import ops, ref
from repro.kernels.gf2_xor import gf2_matmul_pallas
from repro.kernels.gf_matmul import gf_matmul_pallas

SHAPES = [
    (1, 1, 1),
    (4, 3, 17),
    (10, 10, 1000),
    (7, 5, 2048),       # exactly one tile
    (3, 9, 2049),       # tile + 1 (padding path)
]


@pytest.mark.parametrize("s", [1, 2, 4, 8])
@pytest.mark.parametrize("n,K,L", SHAPES)
def test_gf_matmul_matches_oracle(s, n, K, L):
    f = get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 1000 + K * 10 + s))
    A = f.random_elements(k1, (n, K))
    P = f.random_elements(k2, (K, L))
    got = gf_matmul_pallas(A, P, s=s, interpret=True)
    want = ref.gf_matmul_ref(A, P, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,K,L", SHAPES)
def test_gf2_kernel_matches_oracle(n, K, L):
    key = jax.random.PRNGKey(n + K + L)
    k1, k2 = jax.random.split(key)
    A = jax.random.randint(k1, (n, K), 0, 2, jnp.int32).astype(jnp.uint8)
    P = jax.random.randint(k2, (K, L), 0, 256, jnp.int32).astype(jnp.uint8)
    got = gf2_matmul_pallas(A, P, interpret=True)
    want = ref.gf2_matmul_ref(A, P)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gf2_kernel_equals_gf_matmul_on_bits():
    """For s=1 the two kernels implement the same math."""
    f = get_field(1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    A = f.random_elements(k1, (6, 6))
    P = f.random_elements(k2, (6, 300))
    a = gf_matmul_pallas(A, P, s=1, interpret=True)
    b = gf2_matmul_pallas(A, P, interpret=True)
    np.testing.assert_array_equal(np.asarray(a & 1), np.asarray(b & 1))


@pytest.mark.parametrize("block_l", [128, 512, 2048])
def test_block_size_invariance(block_l):
    f = get_field(8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    A = f.random_elements(k1, (8, 8))
    P = f.random_elements(k2, (8, 3000))
    got = gf_matmul_pallas(A, P, s=8, block_l=block_l, interpret=True)
    want = ref.gf_matmul_ref(A, P, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_dispatch():
    f = get_field(8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    A = f.random_elements(k1, (5, 5))
    P = f.random_elements(k2, (5, 100))
    for impl in ("jnp", "pallas", "auto"):
        got = ops.gf_matmul(A, P, s=8, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.gf_matmul_ref(A, P, 8)))


@pytest.mark.parametrize("S,H,hd,bq,bk", [
    (128, 2, 16, 64, 64),
    (192, 1, 32, 64, 64),     # padding path (192 % 64 == 0; q pad no-op)
    (100, 2, 16, 64, 64),     # ragged S -> causal padding path
])
def test_flash_attention_matches_oracle(S, H, hd, bq, bk):
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import _attend
    key = jax.random.PRNGKey(S + H)
    B = 2
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = _attend(q, k, v, causal=True, window=None, q_offset=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import _attend
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 1, 128, 2, 32
    q = jax.random.normal(key, (B, S, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, S, H, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, S, H, hd)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True)
    want = _attend(q, k, v, causal=True, window=None, q_offset=0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_encode_decode_through_kernel():
    """End-to-end: Pallas encode -> GE decode recovers packets."""
    from repro.core import rlnc
    from repro.core.gf import ge_solve
    s, K, L = 8, 10, 5000
    f = get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    P = f.random_elements(k1, (K, L))
    A = rlnc.random_coding_matrix(k2, K, K, s)
    C = gf_matmul_pallas(A, P, s=s, interpret=True)
    ok, X = ge_solve(f, A, C)
    if bool(ok):
        np.testing.assert_array_equal(np.asarray(X), np.asarray(P))
