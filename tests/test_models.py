"""Per-architecture smoke tests (REDUCED configs, CPU): one forward /
train-loss step + prefill/decode, asserting shapes and finiteness —
deliverable (f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced_config
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=16):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend:
        batch["memory"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_train_step(arch, key):
    cfg = reduced_config(arch)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = tf.init_lm(key, cfg)
    batch = _batch(cfg, key)
    loss, metrics = tf.lm_loss(params, batch, cfg, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # one actual gradient step moves the loss
    grads = jax.grad(lambda p: tf.lm_loss(p, batch, cfg, remat=False)[0])(
        params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_prefill_decode(arch, key):
    cfg = reduced_config(arch)
    B, S = 2, 16
    params = tf.init_lm(key, cfg)
    batch = _batch(cfg, key, B, S)
    logits, cache = tf.prefill(params, batch["tokens"], cfg,
                               cache_len=S + 8,
                               memory=batch.get("memory"))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = tf.decode_step(params, tok, cache, cfg)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[..., :cfg.vocab_size], -1) \
            .astype(jnp.int32)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "llama3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek_v2_236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3_8b": (36, 4096, 32, 8, 12288, 151936),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == KV, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
        cfg.validate()
    # MoE specifics
    a = get_config("arctic_480b").moe
    assert a.num_experts == 128 and a.top_k == 2 and a.dense_residual
    dsv = get_config("deepseek_v2_236b")
    assert dsv.moe.num_experts == 160 and dsv.moe.top_k == 6
    assert dsv.moe.num_shared_experts == 2
    assert dsv.mla.kv_lora_rank == 512


def test_decode_matches_forward_full_cache():
    """Greedy decode through a full (non-windowed) cache must produce
    the same last-token logits as a fresh forward pass on the grown
    sequence (qwen3 reduced; exactness up to bf16 accumulation)."""
    cfg = reduced_config("qwen3_8b").with_overrides(window=None)
    key = jax.random.PRNGKey(1)
    params = tf.init_lm(key, cfg)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    _, cache = tf.prefill(params, toks, cfg, cache_len=S + 4)
    nxt = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0,
                             cfg.vocab_size)
    dec_logits, _ = tf.decode_step(params, nxt, cache, cfg)
    grown = jnp.concatenate([toks, nxt], axis=1)
    h, _ = tf.forward_hidden(params, grown, cfg)
    from repro.models.transformer import _lm_logits
    from repro.models.layers import norm_apply
    ref_logits = _lm_logits(
        params, norm_apply(params["final_norm"], h[:, -1:], cfg.norm), cfg)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ref_logits, np.float32), rtol=0.08, atol=0.05)


def test_mlstm_chunkwise_matches_recurrent():
    """mLSTM: chunked-parallel prefill state == step-by-step decode
    state (same math, different schedules)."""
    from repro.models import ssm
    cfg = reduced_config("xlstm_125m")
    key = jax.random.PRNGKey(2)
    p = ssm.init_mlstm(key, cfg)
    B, S = 2, 19
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.3

    # prefill in one chunked call
    st0 = ssm.make_mlstm_state(cfg, B)
    _, st_par = ssm.apply_mlstm(p, x, cfg, state=st0)
    # decode token by token
    st = ssm.make_mlstm_state(cfg, B)
    for t in range(S):
        _, st = ssm.apply_mlstm(p, x[:, t:t + 1], cfg, state=st)
    np.testing.assert_allclose(np.asarray(st_par["C"]),
                               np.asarray(st["C"]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_par["n"]),
                               np.asarray(st["n"]), rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_decode():
    from repro.models import ssm
    cfg = reduced_config("recurrentgemma_9b")
    key = jax.random.PRNGKey(3)
    p = ssm.init_rglru(key, cfg)
    B, S = 2, 11
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.5
    st0 = ssm.make_rglru_state(cfg, B)
    y_par, st_par = ssm.apply_rglru(p, x, cfg, state=st0)
    st = ssm.make_rglru_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = ssm.apply_rglru(p, x[:, t:t + 1], cfg, state=st)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(st_par["h"]),
                               np.asarray(st["h"]), rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_direct():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(4)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    direct = attn._attend(q, k, v, causal=True, window=None, q_offset=0)
    chunked = attn._attend_chunked(q, k, v, causal=True, window=None,
                                   chunk=16)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)
    # windowed variant
    d2 = attn._attend(q, k, v, causal=True, window=8, q_offset=0)
    c2 = attn._attend_chunked(q, k, v, causal=True, window=8, chunk=16)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(c2),
                               rtol=1e-5, atol=1e-5)
