"""Engine-side recoding + the fused multi-edge hierarchy round.

Two invariants anchor this layer:

* recoding composes linearly (Prop. 2): η sequential relay recodes are
  bit-identical to ONE recode with the product mixing matrix;
* `CodingEngine.multi_edge_round` — the whole edge tier as one fused
  chunk-streamed dispatch — is bit-exact vs the per-edge reference
  path for every edge count, spare budget, and WAN channel, while
  issuing strictly fewer L-sized kernel dispatches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy, rlnc
from repro.core.channel import ErasureChannel, MultiHopChannel
from repro.core.fednc import FedNCConfig
from repro.core.gf import get_field
from repro.engine import CodingEngine, EngineConfig


def _engine(chunk_l=128):
    return CodingEngine(EngineConfig(s=8, kernel="jnp_packed",
                                     chunk_l=chunk_l))


# ---------------------------------------------------------------------------
# recode: linear composition (Prop. 2's η-hop relay)
# ---------------------------------------------------------------------------

def test_recode_composes_linearly_fixed():
    """η sequential recodes ≡ one recode with the product matrix."""
    s, K, L, eta = 8, 5, 333, 4
    f = get_field(s)
    eng = _engine()
    P = f.random_elements(jax.random.PRNGKey(0), (K, L))
    batch = eng.encode(P, eng.coding_matrix(jax.random.PRNGKey(1), K, K))

    hops = [f.random_elements(jax.random.PRNGKey(10 + h), (K, K))
            for h in range(eta)]
    seq = batch
    for R in hops:
        seq = eng.recode_with(R, seq)
    prod = jnp.eye(K, dtype=jnp.uint8)
    for R in hops:
        prod = f.matmul(R, prod)            # R_eta ··· R_1
    once = eng.recode_with(prod, batch)
    np.testing.assert_array_equal(np.asarray(seq.A), np.asarray(once.A))
    np.testing.assert_array_equal(np.asarray(seq.C), np.asarray(once.C))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(K=st.integers(2, 6), eta=st.integers(1, 4),
           seed=st.integers(0, 2**16))
    def test_recode_composition_property(K, eta, seed):
        """Property form: random shapes/hop counts, and the composed
        batch still satisfies the relay invariant C' = A'·P."""
        s, L = 8, 64
        f = get_field(s)
        eng = _engine(chunk_l=32)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        P = f.random_elements(k1, (K, L))
        batch = eng.encode(P, eng.coding_matrix(k2, K + 1, K))

        seq = batch
        prod = jnp.eye(batch.n, dtype=jnp.uint8)
        for h in range(eta):
            kh = jax.random.fold_in(jax.random.PRNGKey(seed), h)
            R = f.random_elements(kh, (batch.n, seq.n))
            seq = eng.recode_with(R, seq)
            prod = f.matmul(R, prod)
        once = eng.recode_with(prod, batch)
        np.testing.assert_array_equal(np.asarray(seq.A),
                                      np.asarray(once.A))
        np.testing.assert_array_equal(np.asarray(seq.C),
                                      np.asarray(once.C))
        # relay invariant: the composed tuples still encode P
        np.testing.assert_array_equal(np.asarray(f.matmul(seq.A, P)),
                                      np.asarray(seq.C))


def test_engine_recode_matches_rlnc_adapter():
    """rlnc.recode is a thin adapter: same draw, same bytes."""
    s, K, L = 8, 4, 100
    f = get_field(s)
    eng = _engine()
    P = f.random_elements(jax.random.PRNGKey(2), (K, L))
    batch = eng.encode(P, eng.coding_matrix(jax.random.PRNGKey(3), K, K))
    key = jax.random.PRNGKey(4)
    a = eng.recode(batch, key, n_out=6)
    b = rlnc.recode(batch, key, n_out=6, s=s)
    np.testing.assert_array_equal(np.asarray(a.A), np.asarray(b.A))
    np.testing.assert_array_equal(np.asarray(a.C), np.asarray(b.C))


# ---------------------------------------------------------------------------
# multi_edge_round: bit-exact vs the per-edge reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E", [1, 2, 4])
@pytest.mark.parametrize("wan", ["ideal", "erasure", "multihop"])
def test_multi_edge_round_bit_exact_vs_per_edge_reference(E, wan):
    """Same PRNG streams in, same bytes out — across edge counts, with
    n_e > K_e spares, under WAN erasures and multi-hop recoding."""
    s, K, L = 8, 8, 517                       # odd L: chunk pad path
    cfg = FedNCConfig(s=s, kernel_impl="jnp_packed", chunk_l=128)
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(E), (K, L))
    edges = hierarchy.partition_edges(K, E)
    eng = _engine()

    agree, decoded = 0, 0
    for seed in range(6):
        key = jax.random.PRNGKey(100 * E + seed)
        if wan == "ideal":
            ch_a = ch_b = None
        elif wan == "erasure":
            ch_a = ErasureChannel(p_erase=0.25, seed=seed)
            ch_b = ErasureChannel(p_erase=0.25, seed=seed)
        else:
            ch_a = MultiHopChannel(eta=2, seed=seed)
            ch_b = MultiHopChannel(eta=2, seed=seed)
        a = eng.multi_edge_round(P, key, [e.client_ids for e in edges],
                                 spare_per_edge=2, wan_channel=ch_a)
        b = hierarchy.per_edge_round_reference(
            P, edges, cfg, key, spare_per_edge=2, wan_channel=ch_b)
        assert a.ok == b.ok
        if a.report is not None or b.report is not None:
            assert a.report.delivered == b.report.delivered
            assert a.report.decodable == b.report.decodable
        if a.ok:
            decoded += 1
            np.testing.assert_array_equal(np.asarray(a.packets),
                                          np.asarray(b.packets))
            # and both recovered the original packets
            np.testing.assert_array_equal(np.asarray(a.packets),
                                          np.asarray(P))
        agree += 1
    assert agree == 6
    if wan == "ideal":
        assert decoded == 6       # spares make the ideal stack full rank


def test_multi_edge_round_fewer_dispatches():
    """The fused round's L-sized dispatch count is independent of E;
    the per-edge reference grows linearly with E.  Counted via each
    engine's obs `engine.dispatches` counter (the counters are
    monotonic, so rounds are measured as before/after diffs)."""
    s, K, L = 8, 8, 1024
    cfg = FedNCConfig(s=s, kernel_impl="jnp_packed", chunk_l=256)
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(0), (K, L))
    from repro.core.fednc import engine_for
    eng = _engine(chunk_l=256)
    ref_eng = engine_for(cfg)       # the reference path's cached engine
    ctr = eng.metrics.counter("engine.dispatches")
    ref_ctr = ref_eng.metrics.counter("engine.dispatches")
    counts = {}
    for E in (2, 4):
        edges = hierarchy.partition_edges(K, E)
        before = ctr.value
        out = eng.multi_edge_round(P, jax.random.PRNGKey(1),
                                   [e.client_ids for e in edges],
                                   spare_per_edge=1)
        counts[("fused", E)] = ctr.value - before
        assert out.ok
        before = ref_ctr.value
        ref = hierarchy.per_edge_round_reference(
            P, edges, cfg, jax.random.PRNGKey(1), spare_per_edge=1)
        counts[("ref", E)] = ref_ctr.value - before
        assert ref.ok
    # fused: one _stream with 2 matmuls per chunk, E-independent
    nc = -(-L // 256)
    assert counts[("fused", 2)] == counts[("fused", 4)] == 2 * nc
    # per-edge reference grows with E and always exceeds the fused count
    assert counts[("ref", 2)] > counts[("fused", 2)]
    assert counts[("ref", 4)] > counts[("ref", 2)]


def test_hierarchical_round_fused_equals_reference_end_to_end():
    """hierarchical_fednc_round(fused=True) == (fused=False) at the
    aggregated-model level, WAN erasures included."""
    key0 = jax.random.PRNGKey(0)
    clients = [{"w": jax.random.normal(jax.random.fold_in(key0, i),
                                       (8, 3))} for i in range(6)]
    weights = [1 / 6] * 6
    prev = clients[0]
    cfg = FedNCConfig(s=8)
    for seed in range(5):
        res_f = hierarchy.hierarchical_fednc_round(
            clients, weights, prev, cfg, jax.random.PRNGKey(seed),
            num_edges=2, spare_per_edge=2,
            wan_channel=ErasureChannel(0.2, seed=seed), fused=True)
        res_r = hierarchy.hierarchical_fednc_round(
            clients, weights, prev, cfg, jax.random.PRNGKey(seed),
            num_edges=2, spare_per_edge=2,
            wan_channel=ErasureChannel(0.2, seed=seed), fused=False)
        assert res_f.decoded == res_r.decoded
        np.testing.assert_array_equal(
            np.asarray(res_f.global_params["w"]),
            np.asarray(res_r.global_params["w"]))


# ---------------------------------------------------------------------------
# federation strategy adapter
# ---------------------------------------------------------------------------

def test_hierarchical_strategy_aggregates():
    from repro.federation import HierarchicalFedNCStrategy
    from repro.core import fednc
    key0 = jax.random.PRNGKey(7)
    clients = [{"w": jax.random.normal(jax.random.fold_in(key0, i),
                                       (4, 2))} for i in range(4)]
    weights = [0.25] * 4
    prev = clients[0]
    strat = HierarchicalFedNCStrategy(config=FedNCConfig(s=8),
                                      num_edges=2, spare_per_edge=1)
    res = strat.aggregate(clients, weights, prev,
                          np.random.default_rng(0))
    assert res.decoded
    ref = fednc.fedavg_round(clients, weights, prev)
    np.testing.assert_array_equal(np.asarray(res.global_params["w"]),
                                  np.asarray(ref.global_params["w"]))
