"""End-to-end behaviour tests for the FedNC system.

The headline system property: a federated round that ships its model
packets through RLNC over a lossy/blind network produces EXACTLY the
aggregation FedAvg would have produced with perfect knowledge — while
FedAvg itself degrades under the same channel.  Plus checkpointing,
transformer-FL integration, and the loss-chunking equivalence.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import fednc
from repro.core.channel import BlindBoxChannel
from repro.core.fednc import FedNCConfig
from repro.models import transformer as tf


def test_fednc_round_on_transformer_params():
    """FedNC packets carry a real (reduced) transformer's parameter
    pytree bit-exactly through encode->decode."""
    cfg = reduced_config("qwen3_4b")
    key = jax.random.PRNGKey(0)
    clients = [tf.init_lm(jax.random.fold_in(key, i), cfg)
               for i in range(3)]
    res = fednc.fednc_round(clients, [1, 1, 1], clients[0],
                            FedNCConfig(s=8), jax.random.PRNGKey(5))
    ref = fednc.fedavg_round(clients, [1, 1, 1], clients[0])
    assert res.decoded
    for (_p1, l1), (_p2, l2) in zip(
            jax.tree_util.tree_leaves_with_path(res.global_params),
            jax.tree_util.tree_leaves_with_path(ref.global_params),
            strict=True):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_blind_box_fednc_beats_fedavg_on_coverage():
    """Under blind-box reception with budget=K, FedNC aggregates all K
    clients (full rank w.h.p. at s=8) while FedAvg hears only the
    distinct subset (coupon collector) — paper Prop. 1 at system level."""
    from repro.federation.server import FedAvgStrategy, FedNCStrategy
    key = jax.random.PRNGKey(1)
    K = 8
    clients = [{"w": jax.random.normal(jax.random.fold_in(key, i), (6,))}
               for i in range(K)]
    weights = [1.0 / K] * K

    nc_cover, avg_cover = [], []
    for seed in range(10):
        rng = np.random.default_rng(seed)
        st_nc = FedNCStrategy(config=FedNCConfig(s=8),
                              channel=BlindBoxChannel(budget=K))
        r1 = st_nc.aggregate(clients, weights, clients[0], rng)
        nc_cover.append(r1.n_aggregated if r1.decoded else 0)
        st_avg = FedAvgStrategy(channel=BlindBoxChannel(budget=K))
        r2 = st_avg.aggregate(clients, weights, clients[0],
                              np.random.default_rng(seed))
        avg_cover.append(r2.report.distinct_sources)
    assert np.mean(nc_cover) > np.mean(avg_cover)
    assert max(avg_cover) <= K


def test_checkpoint_roundtrip():
    from repro.checkpoint import load_pytree, save_pytree
    cfg = reduced_config("xlstm_125m")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_pytree(path, params, metadata={"arch": cfg.name})
        back = load_pytree(path, params)
        for l1, l2 in zip(jax.tree_util.tree_leaves(params),
                          jax.tree_util.tree_leaves(back),
                          strict=True):
            np.testing.assert_array_equal(
                np.asarray(l1, np.float32), np.asarray(l2, np.float32))


def test_chunked_lm_loss_matches_direct():
    """The seq-chunked LM head (never materializing (B,S,V) logits)
    equals the direct computation."""
    cfg = reduced_config("qwen2_72b")
    key = jax.random.PRNGKey(2)
    params = tf.init_lm(key, cfg)
    B, S = 2, 24
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    loss, _ = tf.lm_loss(params, batch, cfg, remat=False)

    # direct reference
    h, aux = tf.forward_hidden(params, tok, cfg)
    logits = tf._lm_logits(params, h, cfg).astype(jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    logits = jnp.where(vmask[None, None], logits, -1e30)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, tok[..., None], -1)[..., 0]
    ref = jnp.mean(nll) + aux
    assert float(loss) == pytest.approx(float(ref), rel=1e-4)


def test_train_step_integration_reduced():
    """make_train_step end-to-end on 1 device with K=2 synthetic
    clients: params move, loss finite, all agg modes agree."""
    from repro.launch.steps import make_train_step
    from repro.optim import adamw
    cfg = reduced_config("qwen3_4b")
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    tok = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}

    outs = {}
    for mode in ("plain", "fednc_naive", "fednc_blocked"):
        step = jax.jit(make_train_step(cfg, opt, num_clients=2,
                                       agg_mode=mode))
        p2, o2, loss = step(params, opt_state, batch,
                            jax.random.PRNGKey(1))
        assert np.isfinite(float(loss))
        outs[mode] = p2
    # the coded aggregations decode to the plain mean -> same update
    l_plain = jax.tree_util.tree_leaves(outs["plain"])
    for mode in ("fednc_naive", "fednc_blocked"):
        for a, b in zip(l_plain, jax.tree_util.tree_leaves(outs[mode]),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
