"""The CI lint gate, reproduced locally when ruff is available.

The container image does not ship ruff (it is a dev dependency,
pinned in requirements-dev.txt and installed by the CI lint job), so
this wrapper skips rather than fails where the tool is absent — same
convention as the hypothesis importorskip in the property tests.
"""
import pathlib
import shutil
import subprocess

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.skipif(shutil.which("ruff") is None,
                    reason="ruff not installed (CI installs it from "
                           "requirements-dev.txt)")
def test_ruff_clean():
    proc = subprocess.run(["ruff", "check", "."], cwd=ROOT,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, (
        f"ruff violations:\n{proc.stdout}\n{proc.stderr}")
