"""repro.sim + engine.stream: the temporal axis, tested.

* StreamDecoder fed packets one at a time — any arrival order, with
  redundant/dependent rows interleaved — must be bit-exact with the
  batch CodingEngine decode (GF arithmetic has no rounding; any
  mismatch is a real bug).
* The simulator must be deterministic by seed, account for dropout
  exactly, and reproduce Prop. 1's draw counts as measurements.
* BlindBoxChannel's new `plan_transform` must consume the same RNG
  stream as the host-side draw (the oracle) and decode identically
  through the fused round path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ArrivalSchedule, BlindBoxChannel
from repro.core.gf import get_field, rank as gf_rank
from repro.core.rlnc import EncodedBatch
from repro.engine import (CodingEngine, EngineConfig, StreamDecoder,
                          incremental_select, stream_decode)
from repro.sim import (STRAGGLER_PROFILES, DistSpec, NetworkSimulator,
                       PopulationConfig, SimConfig, arrival_stream)


# ---------------------------------------------------------------------------
# StreamDecoder vs batch decode
# ---------------------------------------------------------------------------

def _coded(s, K, L, n, seed):
    f = get_field(s)
    kp, ka = jax.random.split(jax.random.PRNGKey(seed))
    P = f.random_elements(kp, (K, L))
    A = f.random_elements(ka, (n, K))
    return f, P, A, f.matmul(A, P)


def test_stream_decoder_matches_batch_decode_in_order():
    s, K, L = 8, 6, 40
    f, P, A, C = _coded(s, K, L, 10, seed=0)
    ok, P_hat, consumed = stream_decode(EncodedBatch(A=A, C=C), s)
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp"))
    ok_b, P_b = eng.decode(EncodedBatch(A=A, C=C))
    assert ok == bool(ok_b)
    np.testing.assert_array_equal(np.asarray(P_hat), np.asarray(P_b))
    np.testing.assert_array_equal(np.asarray(P_hat), np.asarray(P))
    assert consumed <= 10


def test_stream_decoder_dependent_rows_interleaved():
    """Duplicates and GF-linear combinations must be consumed as
    redundant (rank unchanged) without corrupting the decode."""
    s, K, L = 8, 5, 30
    f, P, A, C = _coded(s, K, L, 5, seed=1)
    if int(gf_rank(f, A)) < K:
        pytest.skip("unlucky singular draw")
    dec = StreamDecoder(K=K, L=L, s=s)
    # interleave: row0, dup(row0), row1, combo(0,1), rows 2..4
    combo_a = f.add(A[0], f.mul(jnp.uint8(7), A[1]))
    combo_c = f.add(C[0], f.mul(jnp.uint8(7), C[1]))
    feed = [(A[0], C[0]), (A[0], C[0]), (A[1], C[1]),
            (combo_a, combo_c), (A[2], C[2]), (A[3], C[3]),
            (A[4], C[4])]
    ranks = [dec.push(a, c) for a, c in feed]
    assert ranks == [1, 1, 2, 2, 3, 4, 5]
    assert dec.decoded_at == 7 and dec.arrivals == 7
    ok, P_hat = dec.decode()
    assert ok
    np.testing.assert_array_equal(np.asarray(P_hat), np.asarray(P))


def test_stream_decoder_ingest_equals_pushes():
    s, K, L = 4, 5, 17
    f, P, A, C = _coded(s, K, L, 12, seed=2)
    one = StreamDecoder(K=K, L=L, s=s)
    ranks_push = [one.push(A[g], C[g]) for g in range(12)]
    bulk = StreamDecoder(K=K, L=L, s=s)
    ranks_bulk = bulk.ingest(A, C)
    assert ranks_push == list(ranks_bulk)
    assert one.decoded_at == bulk.decoded_at
    np.testing.assert_array_equal(np.asarray(one.decode()[1]),
                                  np.asarray(bulk.decode()[1]))


def test_stream_decoder_rank_short_stream():
    """Fewer than K independent arrivals: FILLING, decode refuses."""
    s, K = 8, 6
    f, P, A, C = _coded(s, K, 10, 4, seed=3)
    dec = StreamDecoder(K=K, L=10, s=s)
    dec.ingest(A, C)
    assert dec.state == "FILLING" and not dec.complete
    ok, out = dec.decode()
    assert not ok and out is None


def test_stream_decoder_agrees_with_incremental_select():
    """The decoder's useful arrivals are exactly the rows the engine's
    on-device selector picks — same reduced-basis rule."""
    s, K = 8, 6
    f, P, A, C = _coded(s, K, 8, 15, seed=4)
    dec = StreamDecoder(K=K, L=8, s=s)
    prev, useful = 0, []
    for g in range(15):
        r = dec.push(A[g], C[g])
        if r > prev:
            useful.append(g)
        prev = r
    ok, idx, count = incremental_select(A, s)
    assert bool(ok)
    assert useful == list(np.asarray(idx)[:int(count)])


def _any_order_case(s, K, L, extra, seed):
    """Shared body: a coded batch plus `extra` dependent rows, fed in
    a shuffled arrival order, must match the batch engine decode —
    bit-exact — whenever rank K is reachable."""
    f = get_field(s)
    rng = np.random.default_rng(seed)
    kp, ka, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    P = f.random_elements(kp, (K, L))
    A = f.random_elements(ka, (K + 2, K))
    if extra:
        # dependent rows: random GF mixtures of the real ones
        M = f.random_elements(km, (extra, K + 2))
        A = jnp.concatenate([A, f.matmul(M, A)], axis=0)
    C = f.matmul(A, P)
    order = rng.permutation(A.shape[0])
    ok, P_hat, consumed = stream_decode(
        EncodedBatch(A=A, C=C), s, order=order)
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp"))
    ok_b, P_b = eng.decode(EncodedBatch(A=A, C=C))
    assert ok == bool(ok_b)    # same rows, same rank verdict
    if ok:
        np.testing.assert_array_equal(np.asarray(P_hat),
                                      np.asarray(P_b))
        np.testing.assert_array_equal(np.asarray(P_hat), np.asarray(P))
        assert consumed <= A.shape[0]


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(s=st.sampled_from([1, 2, 4, 8]), K=st.integers(2, 6),
           L=st.integers(1, 24), extra=st.integers(0, 6),
           seed=st.integers(0, 2**30))
    def test_stream_decoder_any_order_property(s, K, L, extra, seed):
        _any_order_case(s, K, L, extra, seed)
else:
    @pytest.mark.parametrize("s,K,L,extra,seed", [
        (8, 5, 16, 3, 0), (4, 6, 9, 0, 1), (2, 3, 24, 6, 2),
        (1, 4, 7, 4, 3), (8, 2, 1, 1, 4), (1, 6, 12, 6, 5),
    ])
    def test_stream_decoder_any_order_cases(s, K, L, extra, seed):
        """Deterministic sweep standing in when hypothesis is absent
        (pip install -r requirements-dev.txt for the full search)."""
        _any_order_case(s, K, L, extra, seed)


# ---------------------------------------------------------------------------
# ArrivalSchedule + channel plumbing
# ---------------------------------------------------------------------------

def test_arrival_schedule_orders_and_clocks():
    sched = ArrivalSchedule(np.asarray([3.0, 1.0, 2.0]))
    assert list(sched.order) == [1, 2, 0]
    assert sched.time_of(1) == 1.0 and sched.time_of(3) == 3.0
    with pytest.raises(ValueError):
        sched.time_of(4)


def test_blind_box_plan_matches_host_oracle():
    """plan_transform consumes the same RNG stream as the host-side
    draw: equal seeds give identical sampling-with-replacement draws."""
    planned = BlindBoxChannel(budget=30, seed=9).plan_transform(12, 8)
    oracle = np.random.default_rng(9).integers(0, 12, size=30)
    np.testing.assert_array_equal(planned.idx, oracle)


def test_blind_box_fused_round_matches_stagewise():
    """The fused round through plan_transform decodes bit-identically
    to stage-wise transmit_encoded + decode on the same RNG stream."""
    s, K, L = 8, 6, 120
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(0), (K, L))
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp", chunk_l=64))
    key = jax.random.PRNGKey(42)
    out = eng.round(P, key, channel=BlindBoxChannel(budget=20, seed=3))
    # stagewise oracle, same coding matrix + channel RNG stream
    A = eng.coding_matrix(key, K, K)
    batch = eng.encode(P, A)
    rx, rep = BlindBoxChannel(budget=20, seed=3).transmit_encoded(
        batch, s)
    ok, P_hat = eng.decode(rx)
    assert out.ok == bool(ok)
    if out.ok:
        np.testing.assert_array_equal(np.asarray(out.packets),
                                      np.asarray(P_hat))
        np.testing.assert_array_equal(np.asarray(out.packets),
                                      np.asarray(P))


def test_blind_box_small_budget_fails_cleanly():
    s, K = 8, 6
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(1), (K, 50))
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp"))
    out = eng.round(P, jax.random.PRNGKey(2),
                    channel=BlindBoxChannel(budget=K - 2, seed=0))
    assert not out.ok and out.packets is None
    assert out.report.delivered == K - 2


# ---------------------------------------------------------------------------
# Simulator: determinism, dropout accounting, Prop. 1 as measurement
# ---------------------------------------------------------------------------

def _cfg(**kw):
    pop = {"n_clients": kw.pop("n_clients", 2000)}
    for f_ in ("p_dropout", "p_churn"):
        if f_ in kw:
            pop[f_] = kw.pop(f_)
    return SimConfig(population=PopulationConfig(**pop), **kw)


def test_simulator_deterministic_by_seed():
    cfg = _cfg(clients_per_round=24, seed=11,
               gap=STRAGGLER_PROFILES["pareto"], p_dropout=0.05)
    a = NetworkSimulator(cfg).run(25)
    b = NetworkSimulator(cfg).run(25)
    assert a.rounds == b.rounds
    c = NetworkSimulator(_cfg(clients_per_round=24, seed=12,
                              gap=STRAGGLER_PROFILES["pareto"],
                              p_dropout=0.05)).run(25)
    assert a.rounds != c.rounds


def test_simulator_dropout_accounting():
    cfg = _cfg(clients_per_round=16, p_dropout=0.25, seed=4,
               timeout=200.0)
    trace = NetworkSimulator(cfg).run(40)
    assert any(r.n_dropped > 0 for r in trace.rounds)
    for r in trace.rounds:
        assert r.k == 16 and r.k_live + r.n_dropped == r.k
        # FedNC decodes the survivors' subspace every round
        assert r.fednc_decoded and r.fednc_draws >= r.k_live
        # FedAvg blocks on any missing coupon
        assert r.fedavg_complete == (r.n_dropped == 0)
        assert r.fedavg_heard <= r.k_live
        if r.fedavg_complete:
            assert r.fedavg_heard == r.k_live
            assert r.fedavg_time <= cfg.timeout
        else:
            assert r.fedavg_time == cfg.timeout


def test_simulator_measures_prop1_draw_counts():
    """The measured draw ratio (StreamDecoder rank-K arrivals vs the
    blind-box all-K wait) lands near K·H(K)/K from core.coupon."""
    from repro.core import coupon
    K = 32
    cfg = _cfg(clients_per_round=K, seed=0)
    s = NetworkSimulator(cfg).run(150).summary()
    predicted = (coupon.expected_draws_fedavg(K)
                 / coupon.expected_draws_fednc(K, 8))
    assert s["draw_ratio"] == pytest.approx(predicted, rel=0.10)
    # FedNC consumes ~K arrivals, FedAvg ~K·H(K)
    assert s["fednc_draws_mean"] == pytest.approx(K, rel=0.02)
    assert s["time_to_all_k_mean"] > s["time_to_rank_k_mean"]


def test_simulator_stream_and_stages_decoders_agree():
    """The geometric-stage rank law samples the same distribution the
    StreamDecoder measures: means match across decoder modes."""
    base = dict(clients_per_round=24, seed=6)
    ms = NetworkSimulator(_cfg(decoder="stream", **base)
                          ).run(120).summary()
    mg = NetworkSimulator(_cfg(decoder="stages", **base)
                          ).run(120).summary()
    assert ms["fednc_draws_mean"] == pytest.approx(
        mg["fednc_draws_mean"], rel=0.01)


def test_simulator_churn_replaces_invitations():
    cfg = _cfg(clients_per_round=12, p_churn=0.3, seed=8,
               n_clients=500)
    trace = NetworkSimulator(cfg).run(20)
    assert all(r.k == 12 for r in trace.rounds)
    assert sum(r.n_churned for r in trace.rounds) > 0


def test_arrival_stream_delay_reorders_sources():
    """Per-client delay offsets reorder arrivals (times stay sorted)."""
    rng = np.random.default_rng(0)
    live = np.ones(8, bool)
    slow = np.ones(8)
    ev = arrival_stream(rng, live, slow, DistSpec(), 200,
                        delay=DistSpec("pareto", 5.0, 1.5))
    assert np.all(np.diff(ev.times) >= 0)
    assert ev.n_events == 200
    assert set(ev.sources.tolist()) <= set(range(8))


# ---------------------------------------------------------------------------
# Async strategy end-to-end
# ---------------------------------------------------------------------------

def test_async_strategy_aggregates_rank_k_prefix():
    from repro.core.fednc import FedNCConfig
    from repro.federation import AsyncFedNCStrategy, blind_box_schedule
    params = [{"w": jnp.arange(16, dtype=jnp.float32) * (k + 1),
               "b": jnp.float32(k)} for k in range(5)]
    strat = AsyncFedNCStrategy(
        config=FedNCConfig(s=8), budget=20,
        schedule_fn=blind_box_schedule(STRAGGLER_PROFILES["lognormal"]))
    w = np.full(5, 0.2, np.float32)
    res = strat.aggregate(params, w, params[0],
                          np.random.default_rng(3))
    assert res.decoded and res.n_aggregated == 5
    assert 5 <= res.report.consumed <= 20   # ~K of the 20 sent
    assert np.isfinite(res.report.sim_time)
    want = sum(0.2 * p["w"] for p in params)
    np.testing.assert_allclose(np.asarray(res.global_params["w"]),
                               np.asarray(want), rtol=1e-6)
