"""Packetization: bit-exact pytree <-> symbol roundtrips (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import packets as pkt


@settings(max_examples=30, deadline=None)
@given(s=st.sampled_from([1, 2, 4, 8]), n=st.integers(0, 65))
def test_bytes_symbols_roundtrip(s, n):
    rng = np.random.default_rng(n)
    b = jnp.asarray(rng.integers(0, 256, size=n), jnp.uint8)
    sym = pkt.bytes_to_symbols(b, s)
    assert int(sym.max(initial=0)) < 2**s
    back = pkt.symbols_to_bytes(sym, s)
    assert (back == b).all()


@settings(max_examples=20, deadline=None)
@given(s=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2**16),
       dtype=st.sampled_from(["float32", "bfloat16", "int32", "uint8"]))
def test_pytree_packet_roundtrip(s, seed, dtype):
    key = jax.random.PRNGKey(seed)
    dt = jnp.dtype(dtype)
    if dt == jnp.uint8:
        leaf = jax.random.randint(key, (3, 5), 0, 255, jnp.int32) \
            .astype(jnp.uint8)
    elif dt == jnp.int32:
        leaf = jax.random.randint(key, (7,), -1000, 1000, jnp.int32)
    else:
        leaf = jax.random.normal(key, (4, 3), jnp.float32).astype(dt)
    tree = {"a": leaf, "nested": {"b": leaf[:2] * 2}}
    packet, spec = pkt.pytree_to_packet(tree, s=s)
    back = pkt.packet_to_pytree(packet, spec)
    for k in ("a",):
        assert back[k].dtype == tree[k].dtype
        # bit-exact: compare raw bits, NaN-safe
        a1 = jax.lax.bitcast_convert_type(tree[k], jnp.uint8)
        a2 = jax.lax.bitcast_convert_type(back[k], jnp.uint8)
        assert (a1 == a2).all()


def test_quantize_dequantize():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (64, 8)),
            "b": jax.random.normal(key, (8,)) * 10}
    q, spec = pkt.quantize_pytree(tree, bits=8)
    back = pkt.dequantize_pytree(q, spec)
    for k in tree:
        scale = float(jnp.max(tree[k]) - jnp.min(tree[k])) / 255
        assert float(jnp.max(jnp.abs(back[k] - tree[k]))) <= scale + 1e-6


def test_stack_packets_shape_guard():
    a = jnp.zeros((10,), jnp.uint8)
    b = jnp.zeros((11,), jnp.uint8)
    with pytest.raises(ValueError):
        pkt.stack_packets([a, b])
