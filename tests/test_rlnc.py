"""RLNC encode/recode/decode properties (hypothesis)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import gf, rlnc


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([4, 8]), K=st.integers(2, 8),
       L=st.integers(1, 200), seed=st.integers(0, 2**16))
def test_encode_decode_roundtrip(s, K, L, seed):
    f = gf.get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    P = f.random_elements(k1, (K, L))
    A = rlnc.random_coding_matrix(k2, K, K, s)
    batch = rlnc.encode(P, A, s, impl="jnp")
    ok, X = rlnc.decode(batch, s)
    if bool(ok):
        assert (X == P).all()
    else:
        assert int(gf.rank(f, A)) < K


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([8]), K=st.integers(2, 6),
       seed=st.integers(0, 2**16))
def test_recode_preserves_decodability_semantics(s, K, seed):
    """Recoded tuples still decode to the ORIGINAL packets when the
    composed coding matrix is invertible (relay property, Prop. 2)."""
    f = gf.get_field(s)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    P = f.random_elements(k1, (K, 50))
    A = rlnc.random_coding_matrix(k2, K, K, s)
    batch = rlnc.encode(P, A, s, impl="jnp")
    re = rlnc.recode(batch, k3, K, s)
    # invariant: C' = A'·P for the composed coding matrix A'
    assert (f.matmul(re.A, P) == re.C).all()
    ok, X = rlnc.decode(re, s)
    if bool(ok):
        assert (X == P).all()


def test_systematic_prefix_is_identity():
    A = rlnc.systematic_coding_matrix(jax.random.PRNGKey(0), 7, 5, 8)
    assert (A[:5] == jnp.eye(5, dtype=jnp.uint8)).all()
    assert A.shape == (7, 5)


def test_extra_tuples_survive_erasure():
    """K+2 coded tuples tolerate 2 erasures (robustness, §III-A.3)."""
    s, K, L = 8, 5, 40
    f = gf.get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    P = f.random_elements(k1, (K, L))
    A = rlnc.random_coding_matrix(k2, K + 2, K, s)
    batch = rlnc.encode(P, A, s, impl="jnp")
    surviving = batch[jnp.asarray([0, 2, 3, 5, 6])]  # drop 2
    if bool(rlnc.decodable(surviving, s)):
        picked = rlnc.select_decodable_rows(surviving, s)
        ok, X = rlnc.decode(picked, s)
        assert bool(ok) and (X == P).all()


def test_float_field_roundtrip():
    key = jax.random.PRNGKey(0)
    P = jax.random.normal(key, (6, 100))
    A = rlnc.float_coding_matrix(jax.random.PRNGKey(1), 6, 6)
    C = rlnc.float_encode(P, A)
    ok, X = rlnc.float_decode(A, C)
    assert bool(ok)
    assert float(jnp.max(jnp.abs(X - P))) < 1e-3
