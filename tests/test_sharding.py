"""Sharding rules: divisibility fallbacks, expert-parallel templates.

Uses AbstractMesh (no real devices needed) to evaluate PartitionSpec
rules against the production 16x16 topology inside the single-device
test process."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    try:
        from jax.sharding import AbstractMesh
        return AbstractMesh((16, 16), ("data", "model"))
    except (ImportError, TypeError):
        pytest.skip("AbstractMesh unavailable")


def test_generic_matrix_rule(mesh):
    spec = sh.param_spec_for("decoder/scan/b0/mlp/up/w",
                             (36, 4096, 12288), mesh)
    assert spec == P(None, "data", "model")


def test_non_divisible_replicates(mesh):
    # 56-head arctic projection: out dim divides, in dim divides
    spec = sh.param_spec_for("attn/wq/w", (7000, 56 * 128), mesh)
    # 7000 % 16 != 0 -> replicated on data
    assert spec == P(None, "model")
    spec2 = sh.param_spec_for("attn/wq/w", (118, 118), mesh)
    assert spec2 == P(None, None)


def test_expert_rule(mesh):
    spec = sh.param_spec_for("decoder/scan/b0/moe/w_gate",
                             (59, 160, 5120, 1536), mesh)
    assert spec == P(None, "model", "data", None)
    spec2 = sh.param_spec_for("decoder/prefix/moe/w_down",
                              (128, 4864, 7168), mesh)
    assert spec2 == P("model", "data", None)


def test_embed_rule(mesh):
    spec = sh.param_spec_for("embed/table", (256256, 1024), mesh)
    assert spec == P("model", "data")


def test_scalar_and_bias(mesh):
    assert sh.param_spec_for("gate_attn", (), mesh) == P()
    assert sh.param_spec_for("mlp/up/b", (12288,), mesh) == P(None)


def test_batch_spec(mesh):
    assert sh.batch_spec((256, 4096), mesh) == P("data", None)
    assert sh.batch_spec((1, 1), mesh) == P(None, None)


def test_cache_spec(mesh):
    spec = sh.cache_spec_for("scan/b0/k", (36, 128, 32768, 8, 128), mesh)
    # slots dim -> model; batch dim at template offset -> data? the
    # leading (G, B) dims: template right-aligns on (B, slots, KV, hd)
    assert spec[2] == "model"           # slots
    spec2 = sh.cache_spec_for("prefix/0/ckv", (128, 32768, 512), mesh)
    assert spec2 == P("data", "model", None)


def test_vocab_padding_divides():
    from repro.configs import ARCHITECTURES, get_config
    for a in ARCHITECTURES:
        cfg = get_config(a)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
