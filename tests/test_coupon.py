"""Prop. 1 (coupon collector / blind box) math + simulation."""
import math

import numpy as np
import pytest

from repro.core import coupon


def test_exact_equals_harmonic():
    for K in (1, 2, 5, 10, 50):
        assert coupon.expected_draws_fedavg(K) == pytest.approx(
            K * sum(1 / i for i in range(1, K + 1)))


def test_asymptotic_matches_exact():
    """Paper eq. 5 approximates K·H(K) to O(1/K)."""
    for K in (10, 100, 1000):
        exact = coupon.expected_draws_fedavg(K)
        asym = coupon.expected_draws_fedavg_asymptotic(K)
        assert abs(exact - asym) < 1.0 / K * 10


def test_fednc_draws_close_to_K():
    """FedNC needs ~K draws (O(K)), vs K ln K for FedAvg — the paper's
    headline efficiency claim."""
    for K in (5, 10, 20):
        e = coupon.expected_draws_fednc(K, s=8)
        assert K <= e < K + 0.02
        assert coupon.expected_draws_fedavg(K) > e * math.log(K) * 0.8


def test_simulation_matches_formula():
    K = 8
    sim = coupon.simulate_fedavg_draws(K, trials=400, seed=0)
    expect = coupon.expected_draws_fedavg(K)
    assert np.mean(sim) == pytest.approx(expect, rel=0.15)


def test_fednc_simulation_matches_formula():
    """Vectorized (vmapped incremental-GE) Monte-Carlo leaves the slow
    tier: real GF rank measurements, batched over trials."""
    K = 6
    sim = coupon.simulate_fednc_draws(K, s=8, trials=60, seed=0)
    assert np.mean(sim) == pytest.approx(
        coupon.expected_draws_fednc(K, 8), rel=0.1)


def test_fednc_simulation_small_field_retry_path():
    """s=1 (q=2) makes dependent draws common, exercising both the
    longer stacks and the doubled-stack retry fallback."""
    sim = coupon.simulate_fednc_draws(5, s=1, trials=300, seed=1)
    assert np.mean(sim) == pytest.approx(
        coupon.expected_draws_fednc(5, 1), rel=0.1)


def test_fedavg_simulation_distribution_tail():
    """The geometric-stage decomposition reproduces the collector's
    law, not just its mean: P(G > K·H(K)·2) is small but nonzero."""
    K = 10
    sim = coupon.simulate_fedavg_draws(K, trials=4000, seed=2)
    assert sim.min() >= K
    tail = float(np.mean(sim > 2 * coupon.expected_draws_fedavg(K)))
    assert 0.0 < tail < 0.2
