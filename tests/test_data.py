"""Data pipeline: partitioners (paper §IV-A.2), synthetic sources."""
import numpy as np
import pytest

from repro.data import (iid_partition, make_image_dataset,
                        make_token_stream, mixed_noniid_partition)
from repro.data.partition import client_weights
from repro.data.synthetic import batches


def test_iid_partition_covers_everything():
    ds = make_image_dataset(1000, seed=0)
    parts = iid_partition(ds.labels, 10, seed=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == 1000
    assert len(np.unique(allidx)) == 1000
    # every client sees most classes (uniform categories)
    for p in parts:
        assert len(np.unique(ds.labels[p])) >= 8


def test_mixed_noniid_partition_shapes_and_skew():
    ds = make_image_dataset(2000, seed=0)
    parts = mixed_noniid_partition(ds.labels, 20, seed=2)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000
    assert len(np.unique(allidx)) == 2000
    # shard-dominated clients hold few categories: ~2 shards + 5% iid
    dominant = 0
    for p in parts:
        labels = ds.labels[p]
        counts = np.bincount(labels, minlength=10)
        top2 = np.sort(counts)[-2:].sum()
        if top2 / len(labels) > 0.8:
            dominant += 1
    assert dominant >= 15   # most clients are 2-category dominated


def test_client_weights_normalized():
    parts = [np.arange(10), np.arange(30), np.arange(60)]
    w = client_weights(parts)
    assert w.sum() == pytest.approx(1.0)
    assert w[2] == pytest.approx(0.6)


def test_batches_iterator():
    ds = make_image_dataset(100, seed=3)
    n = 0
    for x, y in batches(ds, 32, epochs=2):
        assert x.shape == (32, 32, 32, 3)
        assert y.shape == (32,)
        n += 1
    assert n == 6   # 3 per epoch x 2


def test_token_stream_plants_structure():
    ts = make_token_stream(128, seed=0)
    b = ts.batch(4, 64)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert b["tokens"].max() < 128
    # planted bigrams: successor entropy must be far below uniform
    big = ts.sample(64, 256)
    pairs = {}
    for row in big:
        for a, b2 in zip(row[:-1], row[1:], strict=True):
            pairs.setdefault(int(a), []).append(int(b2))
    frac_planted = np.mean([
        len(set(v)) < 40 for v in pairs.values() if len(v) >= 8])
    assert frac_planted > 0.5
