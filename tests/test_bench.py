"""Benchmark artifacts stay true (fast tier): scripts/check_bench.py.

Same pattern as tests/test_docs.py — the checker validates presence,
schema, finite values, and the headline bars of every BENCH_*.json in
the repo root, so benchmark drift fails the fast tier exactly like
doc drift already does.
"""
import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_checker(cwd=ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_bench.py")],
        capture_output=True, text=True, timeout=120, cwd=cwd, env=env)


def test_check_bench_passes():
    proc = _run_checker()
    assert proc.returncode == 0, (
        f"benchmark artifacts drifted:\n{proc.stderr}\n{proc.stdout}")


def test_check_bench_catches_broken_sim_artifact(tmp_path):
    """A violated bar (draw ratio off by >10%) must fail the checker:
    copy the tree's checker next to a doctored BENCH_sim.json."""
    sim = json.loads((ROOT / "BENCH_sim.json").read_text())
    key = next(k for k in sim if k.startswith("sim_pop"))
    sim[key]["draw_ratio_rel_err"] = 0.5
    root = tmp_path / "repo"
    (root / "scripts").mkdir(parents=True)
    (root / "scripts" / "check_bench.py").write_text(
        (ROOT / "scripts" / "check_bench.py").read_text())
    for fname in ("BENCH_kernels.json", "BENCH_hierarchy.json"):
        (root / fname).write_text((ROOT / fname).read_text())
    (root / "BENCH_sim.json").write_text(json.dumps(sim))
    env = dict(os.environ)
    proc = subprocess.run(
        [sys.executable, str(root / "scripts" / "check_bench.py")],
        capture_output=True, text=True, timeout=120, env=env)
    assert proc.returncode == 1
    assert "Prop. 1" in proc.stderr
