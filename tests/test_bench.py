"""Benchmark artifacts stay true (fast tier): scripts/check_bench.py.

Same pattern as tests/test_docs.py — the checker validates presence,
schema, finite values, and the headline bars of every BENCH_*.json in
the repo root, so benchmark drift fails the fast tier exactly like
doc drift already does.
"""
import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_checker(cwd=ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_bench.py")],
        capture_output=True, text=True, timeout=120, cwd=cwd, env=env)


def test_check_bench_passes():
    proc = _run_checker()
    assert proc.returncode == 0, (
        f"benchmark artifacts drifted:\n{proc.stderr}\n{proc.stdout}")


def _doctored_tree(tmp_path, replace: dict) -> pathlib.Path:
    """Copy the checker + every artifact into a tmp repo, overriding
    the artifacts named in `replace` with doctored JSON."""
    root = tmp_path / "repo"
    (root / "scripts").mkdir(parents=True, exist_ok=True)
    (root / "scripts" / "check_bench.py").write_text(
        (ROOT / "scripts" / "check_bench.py").read_text())
    for fname in ("BENCH_kernels.json", "BENCH_hierarchy.json",
                  "BENCH_sim.json", "BENCH_serve.json",
                  "BENCH_security.json", "GRID_grid.json",
                  "GRID_smoke.json", "TRACE_serve.json"):
        data = (json.dumps(replace[fname]) if fname in replace
                else (ROOT / fname).read_text())
        (root / fname).write_text(data)
    return root


def _run_doctored(root) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(root / "scripts" / "check_bench.py")],
        capture_output=True, text=True, timeout=120,
        env=dict(os.environ))


def test_check_bench_catches_broken_sim_artifact(tmp_path):
    """A violated bar (draw ratio off by >10%) must fail the checker:
    copy the tree's checker next to a doctored BENCH_sim.json."""
    sim = json.loads((ROOT / "BENCH_sim.json").read_text())
    key = next(k for k in sim if k.startswith("sim_pop"))
    sim[key]["draw_ratio_rel_err"] = 0.5
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_sim.json": sim}))
    assert proc.returncode == 1
    assert "Prop. 1" in proc.stderr


def test_check_bench_catches_broken_grid_artifact(tmp_path):
    """The GRID schema bars: a full grid whose compute-coupled clock
    stopped dominating, or whose delay sweep stopped inflating FedAvg,
    must fail."""
    grid = json.loads((ROOT / "GRID_grid.json").read_text())
    grid["compute_coupling"]["dominates"] = False
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_grid.json": grid}))
    assert proc.returncode == 1
    assert "dominate" in proc.stderr

    grid = json.loads((ROOT / "GRID_grid.json").read_text())
    grid["delay_sweep"]["inflation"][-1] = 1.0
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_grid.json": grid}))
    assert proc.returncode == 1
    assert "inflation" in proc.stderr


def test_check_bench_catches_seeded_regression(tmp_path):
    """A seeded kernel falling below 0.9x its materialized sibling
    must fail — regenerating rows in-kernel is supposed to be ~free."""
    kern = json.loads((ROOT / "BENCH_kernels.json").read_text())
    key = next(k for k in kern
               if k.startswith("seeded_vs_materialized_"))
    kern[key]["x"] = 0.5
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_kernels.json": kern}))
    assert proc.returncode == 1
    assert "seeded bar" in proc.stderr


def test_check_bench_catches_wire_overhead_drift(tmp_path):
    """The wire rows are exact arithmetic, (4+L)/(K+L) — a doctored
    ratio and a dropped K row must both fail."""
    kern = json.loads((ROOT / "BENCH_kernels.json").read_text())
    kern["seeded_wire_overhead_K128"]["ratio"] = 0.5
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_kernels.json": kern}))
    assert proc.returncode == 1
    assert "(4+L)/(K+L)" in proc.stderr

    kern = json.loads((ROOT / "BENCH_kernels.json").read_text())
    del kern["seeded_wire_overhead_K512"]
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_kernels.json": kern}))
    assert proc.returncode == 1
    assert "seeded_wire_overhead_K512" in proc.stderr


def test_check_bench_catches_engine_cell_violations(tmp_path):
    """The grid's engine cells: a seeded cell whose wire ratio did not
    shrink, and a lossless cell that dropped rounds, must fail."""
    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    key = next(k for k, v in smoke["scenarios"].items()
               if v["axes"]["strategy"] == "engine" and v["seeded"])
    smoke["scenarios"][key]["wire_overhead_ratio"] = 1.2
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "did not shrink" in proc.stderr

    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    smoke["scenarios"][key]["decode_rate"] = 0.5
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "lossless" in proc.stderr


def test_check_bench_catches_serve_speedup_regression(tmp_path):
    """Continuous batching falling under 1.5x sequential ingest, or
    losing the >= 8 concurrent jobs the claim is made at, must fail."""
    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["batched_vs_sequential"]["x"] = 1.1
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "1.5x" in proc.stderr

    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["batched_vs_sequential"]["concurrent_jobs"] = 3
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "concurrent" in proc.stderr


def test_check_bench_catches_serve_decode_drift(tmp_path):
    """Batched and sequential modes decoding different payloads, or
    jobs left incomplete, must fail the checker."""
    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["payloads_match"] = False
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "byte-identical" in proc.stderr

    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["serve_batched"]["completed"] = 1
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "decoded only" in proc.stderr


def test_check_bench_smoke_serve_artifact_relaxed(tmp_path):
    """A BENCH_serve_*.json smoke artifact is schema-checked but the
    perf bar is skipped (config.smoke) — while a schema violation in
    the same file still fails."""
    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["config"]["smoke"] = True
    serve["batched_vs_sequential"]["x"] = 0.5
    root = _doctored_tree(tmp_path, {})
    (root / "BENCH_serve_smoke.json").write_text(json.dumps(serve))
    proc = _run_doctored(root)
    assert proc.returncode == 0, proc.stderr

    del serve["serve_sequential"]
    (root / "BENCH_serve_smoke.json").write_text(json.dumps(serve))
    proc = _run_doctored(root)
    assert proc.returncode == 1
    assert "serve_sequential" in proc.stderr


def test_check_bench_catches_broken_metrics_snapshot(tmp_path):
    """The embedded fednc-metrics-v1 snapshot is validated standalone:
    a wrong schema tag and a histogram whose counts disagree with its
    bounds/count must both fail."""
    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["metrics"]["schema"] = "fednc-metrics-v0"
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "fednc-metrics-v1" in proc.stderr

    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    hist = serve["metrics"]["metrics"]["serve.job_latency_s"]
    hist["counts"] = hist["counts"][:-1]          # drop overflow bucket
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "len(bounds)+1" in proc.stderr

    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    serve["metrics"]["metrics"]["serve.job_latency_s"]["count"] += 1
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "sum(counts)" in proc.stderr

    serve = json.loads((ROOT / "BENCH_serve.json").read_text())
    del serve["metrics"]["metrics"]["serve.queue_depth"]
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"BENCH_serve.json": serve}))
    assert proc.returncode == 1
    assert "serve.queue_depth" in proc.stderr


def test_check_bench_catches_broken_trace(tmp_path):
    """TRACE_*.json in the root must be valid Chrome trace-event JSON:
    a duration event stripped of its timestamp, and a wrong schema
    tag, must both fail."""
    trace = json.loads((ROOT / "TRACE_serve.json").read_text())
    span = next(e for e in trace["traceEvents"] if e["ph"] == "X")
    del span["ts"]
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"TRACE_serve.json": trace}))
    assert proc.returncode == 1
    assert "missing 'ts'" in proc.stderr

    trace = json.loads((ROOT / "TRACE_serve.json").read_text())
    trace["otherData"]["schema"] = "not-a-trace"
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"TRACE_serve.json": trace}))
    assert proc.returncode == 1
    assert "fednc-trace-v1" in proc.stderr


def test_check_bench_catches_grid_missing_per_stage(tmp_path):
    """Every grid cell must publish its per-stage wall breakdown; a
    dropped or empty per_stage mapping fails."""
    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    next(iter(smoke["scenarios"].values())).pop("per_stage")
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "per_stage" in proc.stderr

    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    next(iter(smoke["scenarios"].values()))["per_stage"] = {}
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "per_stage" in proc.stderr


def test_check_bench_catches_security_rank_wall_breach(tmp_path):
    """The structural bar: any full leak below full edge capture, or a
    trial leaking below K independent rows, must fail — smoke or not."""
    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    sec["eavesdrop_edge_sweep"]["entries"][0]["full_leak_rate"] = 0.1
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "below full edge capture" in proc.stderr

    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    sec["leak_probability"]["entries"][0]["rank_wall_violations"] = 2
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "below K independent rows" in proc.stderr


def test_check_bench_catches_security_leak_drift(tmp_path):
    """Measured leak rate drifting past its binomial tolerance from the
    closed form must fail."""
    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    entry = sec["leak_probability"]["entries"][0]
    entry["abs_err"] = entry["tol"] * 10 + 0.1
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "from the closed form" in proc.stderr


def test_check_bench_catches_byzantine_misses(tmp_path):
    """A wrong decode accepted past verification always fails; a low
    detection rate fails the full tier but is waived under
    config.smoke (small byzantine round counts are noisy)."""
    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    sec["byzantine_detection"]["entries"][0]["undetected_bad_decodes"] = 1
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "past verification" in proc.stderr

    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    sec["byzantine_detection"]["entries"][0]["detection_rate"] = 0.5
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "detection rate" in proc.stderr

    sec["config"]["smoke"] = True
    root = _doctored_tree(tmp_path, {})
    (root / "BENCH_security_smoke.json").write_text(json.dumps(sec))
    proc = _run_doctored(root)
    assert proc.returncode == 0, proc.stderr


def test_check_bench_catches_unflagged_replays(tmp_path):
    sec = json.loads((ROOT / "BENCH_security.json").read_text())
    sec["replay_detection"]["flagged"] -= 1
    proc = _run_doctored(_doctored_tree(
        tmp_path, {"BENCH_security.json": sec}))
    assert proc.returncode == 1
    assert "replayed headers" in proc.stderr


def test_check_bench_requires_smoke_grid_adversary_cells(tmp_path):
    """GRID_smoke.json must keep >= 2 adversary cells: stripping the
    axis back to all-none fails the checker."""
    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    for entry in smoke["scenarios"].values():
        entry["axes"]["adversary"] = "none"
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "adversary cells" in proc.stderr


def test_check_bench_catches_grid_missing_seed(tmp_path):
    """Every scenario entry must carry its own seed (reproducibility
    is the point of the grid) — smoke artifacts included."""
    smoke = json.loads((ROOT / "GRID_smoke.json").read_text())
    next(iter(smoke["scenarios"].values())).pop("seed")
    proc = _run_doctored(_doctored_tree(tmp_path,
                                        {"GRID_smoke.json": smoke}))
    assert proc.returncode == 1
    assert "seed" in proc.stderr
