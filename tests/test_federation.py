"""End-to-end FL integration: tiny FedNC vs FedAvg runs on synthetic
images — the system-level behaviour the paper's Fig. 3 rests on."""
import jax
import numpy as np
import pytest

from repro.core.fednc import FedNCConfig
from repro.data import iid_partition, make_image_dataset
from repro.federation import (FedAvgStrategy, FedNCStrategy, FLExperiment,
                              LocalTrainer, run_experiment)
from repro.federation.rounds import final_accuracy
from repro.models.cnn import (cnn_accuracy, cnn_loss, init_cnn,
                              merge_bn_stats)
from repro.optim import adam


def _make_exp(strategy, n=400, clients=8, k=4, seed=0):
    ds = make_image_dataset(n, seed=0, size=16)
    test = make_image_dataset(128, seed=99, size=16)
    parts = iid_partition(ds.labels, clients, seed=1)
    trainer = LocalTrainer(
        loss_fn=lambda p, b: cnn_loss(p, b, train=True),
        optimizer=adam(1e-3), local_epochs=1,
        state_merge=merge_bn_stats)
    return FLExperiment(
        trainer=trainer, strategy=strategy, partitions=parts,
        dataset=ds, test_set=test,
        eval_fn=lambda p, x, y: cnn_accuracy(p, x, y),
        clients_per_round=k, batch_size=32, seed=seed,
    ), ds


@pytest.mark.slow
def test_fednc_system_trains():
    strat = FedNCStrategy(config=FedNCConfig(s=8))
    exp, _ = _make_exp(strat)
    params = init_cnn(jax.random.PRNGKey(0), image_size=16)
    logs = run_experiment(exp, params, rounds=5, eval_every=5)
    assert all(l.decoded for l in logs[-2:]) or any(
        l.decoded for l in logs)
    acc = final_accuracy(logs, 1)
    assert acc > 0.15   # better than 10-class chance after 5 rounds


@pytest.mark.slow
def test_fednc_equals_fedavg_under_ideal_channel():
    """With no channel and the same client sampling, FedNC (s=8) and
    FedAvg produce bit-identical global models whenever decode
    succeeds — integration-level version of the Alg.-1 equality."""
    # one round only: FedNC's aggregate consumes an extra RNG draw, so
    # multi-round client sampling would diverge between the two runs —
    # the bit-exactness claim is per-round.
    params = init_cnn(jax.random.PRNGKey(0), image_size=16)
    exp_nc, _ = _make_exp(FedNCStrategy(config=FedNCConfig(s=8)), seed=7)
    exp_avg, _ = _make_exp(FedAvgStrategy(), seed=7)
    logs_nc = run_experiment(exp_nc, params, rounds=1, eval_every=1)
    logs_avg = run_experiment(exp_avg, params, rounds=1, eval_every=1)
    if all(l.decoded for l in logs_nc):
        assert logs_nc[-1].test_acc == pytest.approx(
            logs_avg[-1].test_acc, abs=1e-6)


def test_round_log_fields():
    strat = FedAvgStrategy()
    exp, _ = _make_exp(strat, n=120, clients=4, k=2)
    params = init_cnn(jax.random.PRNGKey(0), image_size=16)
    logs = run_experiment(exp, params, rounds=1)
    assert len(logs) == 1
    l = logs[0]
    assert l.n_aggregated == 2 and l.decoded
    assert np.isfinite(l.train_loss)


@pytest.mark.slow
def test_async_fednc_system_trains():
    """The simulated-clock driver end to end: the async server
    aggregates from the first rank-K prefix of arrivals (~K of the
    multicast budget) and training still converges."""
    from repro.federation import AsyncFedNCStrategy, blind_box_schedule
    from repro.federation.async_rounds import run_async_experiment
    from repro.sim.distributions import STRAGGLER_PROFILES

    strat = AsyncFedNCStrategy(
        config=FedNCConfig(s=8), budget=12,
        schedule_fn=blind_box_schedule(STRAGGLER_PROFILES["pareto"]))
    exp, _ = _make_exp(strat)
    params = init_cnn(jax.random.PRNGKey(0), image_size=16)
    logs = run_async_experiment(exp, params, rounds=5, eval_every=5)
    assert all(l.decoded for l in logs)
    # the whole point: ~K arrivals consumed, never the full budget
    assert all(4 <= l.consumed <= 12 for l in logs)
    assert all(np.isfinite(l.sim_time) and l.sim_time > 0 for l in logs)
    # training converges (test_acc at 5 rounds is too noisy to gate on;
    # per-round aggregates are bit-identical to sync FedNC by
    # construction — the strategy decodes the same packets)
    assert logs[-1].train_loss < 0.5 * logs[0].train_loss
