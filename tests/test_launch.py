"""Launch layer: input specs, shape table, roofline HLO analyzer,
and the serve CLI entry points (subprocess — the launch CLIs must
never drag TPU-only import paths into a bare interpreter)."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.launch import roofline as rl
from repro.launch import specs as sp

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_shapes_table_exact():
    assert sp.SHAPES["train_4k"].seq_len == 4096
    assert sp.SHAPES["train_4k"].global_batch == 256
    assert sp.SHAPES["prefill_32k"].seq_len == 32768
    assert sp.SHAPES["prefill_32k"].global_batch == 32
    assert sp.SHAPES["decode_32k"].global_batch == 128
    assert sp.SHAPES["long_500k"].seq_len == 524288
    assert sp.SHAPES["long_500k"].global_batch == 1


def test_batch_inputs_vlm_audio():
    vlm = get_config("llama-3.2-vision-90b")
    b = sp.batch_inputs(vlm, sp.SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    assert b["memory"].shape == (256, vlm.num_frontend_tokens,
                                 vlm.d_model)
    audio = get_config("seamless-m4t-medium")
    b2 = sp.batch_inputs(audio, sp.SHAPES["prefill_32k"])
    # audio memory length == seq_len (frames)
    assert b2["memory"].shape == (32, 32768, audio.d_model)


def test_decode_window_policy():
    dense = get_config("qwen2-72b")
    assert sp.decode_window(dense, sp.SHAPES["decode_32k"]) is None
    assert sp.decode_window(dense, sp.SHAPES["long_500k"]) == \
        dense.long_context_window
    sc = get_config("starcoder2-15b")        # native SWA stays native
    assert sp.decode_window(sc, sp.SHAPES["long_500k"]) == 4096
    rg = get_config("recurrentgemma-9b")
    assert sp.decode_window(rg, sp.SHAPES["long_500k"]) == 2048


def test_decode_inputs_cache_shapes():
    cfg = get_config("qwen3-8b")
    d = sp.decode_inputs(cfg, sp.SHAPES["decode_32k"])
    caches = jax.tree_util.tree_leaves(d["cache"])
    assert d["token"].shape == (128, 1)
    # full-attention cache: (G, B, slots, KV, hd) stacked over scan
    ks = [l for l in caches if l.ndim == 5]
    assert ks and ks[0].shape[2] == 32768


def test_roofline_trip_count_scaling():
    hlo = """
HloModule test

%cond.1 (arg: (s32[])) -> pred[] {
  %arg = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (arg: (s32[])) -> (s32[]) {
  %arg = (s32[]) parameter(0)
  %x = f32[128,64]{1,0} parameter(1)
  %y = f32[64,32]{1,0} parameter(2)
  %d = f32[128,32]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[1024]{0} all-gather(%d), channel_id=1, replica_groups=[16,16]<=[256]
  ROOT %t = (s32[]) tuple(%arg)
}

ENTRY %main (p0: s32[]) -> s32[] {
  %p0 = s32[] parameter(0)
  %init = (s32[]) tuple(%p0)
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = s32[] get-tuple-element(%w), index=0
}
"""
    a = rl.analyze_hlo(hlo)
    # dot: 2*128*32*64 flops, x7 trips
    assert a.flops == pytest.approx(2 * 128 * 32 * 64 * 7)
    assert a.collective_count == 7
    assert a.collective_bytes == pytest.approx(128 * 32 * 4 * 7)


def test_roofline_terms_bottleneck():
    t = rl.roofline_terms(1e15, 1e9, 1e12)
    assert t["bottleneck"] == "collective"
    assert t["compute_s"] == pytest.approx(1e15 / 197e12)
    assert rl.model_flops(1e9, 1e6, training=True) == 6e15
    assert rl.model_flops(1e9, 1e6, training=False) == 2e15


def _run_cli(args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run([sys.executable, *args], text=True,
                          capture_output=True, timeout=timeout,
                          cwd=ROOT, env=env)


@pytest.mark.parametrize("module", ["repro.launch.serve",
                                    "repro.serve"])
def test_serve_cli_help(module):
    """Both serve entry points answer --help in a clean subprocess —
    no TPU-only imports, no XLA flag side effects, exit 0."""
    proc = _run_cli(["-m", module, "--help"], timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "--jobs" in proc.stdout and "--sequential" in proc.stdout


def test_serve_cli_runs_tiny_trace(tmp_path):
    """The server CLI end-to-end in a subprocess: generate a tiny
    trace, serve it, dump the report."""
    out = tmp_path / "report.json"
    proc = _run_cli(["-m", "repro.launch.serve", "--jobs", "4",
                     "--K", "4", "--L", "16", "--slots", "2",
                     "--g-tick", "3", "--json", str(out)])
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(out.read_text())
    assert doc["completed"] == 4 and doc["mode"] == "batched"
    assert len(doc["completions"]) == 4


def test_arctic_param_count_and_active_fraction():
    # NOTE: do not import repro.launch.dryrun here — it force-sets the
    # 512-device XLA flag, which must not leak into the test process.
    import numpy as np
    from repro.launch.sharding import _key_str
    cfg = get_config("arctic-480b")
    from repro.models import transformer as tf
    shapes = jax.eval_shape(
        lambda: tf.init_lm(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = sum(float(np.prod(l.shape)) for _, l in flat)
    active = 0.0
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        n = float(np.prod(leaf.shape))
        if "moe/w_" in name:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        active += n
    assert total > 4e11                  # ~480B
    assert active < total * 0.1          # top-2 of 128 experts
