"""Algorithm-1 round logic: bit-exact FedNC == FedAvg, skip-on-failure,
channel integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fednc
from repro.core.channel import (BlindBoxChannel, ErasureChannel,
                                MultiHopChannel)
from repro.core.fednc import FedNCConfig


def _clients(n, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        out.append({
            "w": jax.random.normal(k, (8, 4), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (4,)),
        })
    return out


@pytest.mark.parametrize("s", [4, 8])
def test_fednc_equals_fedavg_when_decodable(s):
    """The coding layer is bit-exact (packets are raw float bytes), so
    a successful FedNC round reproduces FedAvg EXACTLY — the paper's
    'no accuracy cost' claim, made literal."""
    clients = _clients(5)
    weights = [0.1, 0.2, 0.3, 0.25, 0.15]
    prev = clients[0]
    cfg = FedNCConfig(s=s, kernel_impl="jnp")
    res_nc = fednc.fednc_round(clients, weights, prev, cfg,
                               jax.random.PRNGKey(42))
    res_avg = fednc.fedavg_round(clients, weights, prev)
    if res_nc.decoded:
        for k in ("w", "b"):
            np.testing.assert_array_equal(
                np.asarray(res_nc.global_params[k]),
                np.asarray(res_avg.global_params[k]))


def test_round_skip_keeps_previous_global():
    """Singular coding matrix -> Alg. 1 else-branch: w_t = w_{t-1}."""
    clients = _clients(4)
    prev = {"w": jnp.full((8, 4), 7.0), "b": jnp.zeros((4,))}
    cfg = FedNCConfig(s=1)  # GF(2): singular with high probability
    skipped = 0
    for seed in range(12):
        res = fednc.fednc_round(clients, [0.25] * 4, prev, cfg,
                                jax.random.PRNGKey(seed))
        if not res.decoded:
            skipped += 1
            assert res.global_params is prev
    assert skipped >= 1    # GF(2) 4x4 singular w.p. ~0.69


def test_erasure_channel_failure_path():
    clients = _clients(4)
    prev = clients[0]
    cfg = FedNCConfig(s=8)
    chan = ErasureChannel(p_erase=0.9, seed=0)
    res = fednc.fednc_round(clients, [0.25] * 4, prev, cfg,
                            jax.random.PRNGKey(0), channel=chan)
    if not res.decoded:
        assert res.global_params is prev
        assert res.report is not None


def test_extra_tuples_beat_erasure():
    """FedNC with K+extra coded tuples tolerates erasures that would
    stall FedAvg (robustness §III-A.3)."""
    clients = _clients(4, seed=3)
    prev = clients[0]
    cfg = FedNCConfig(s=8, extra_tuples=4)
    chan = ErasureChannel(p_erase=0.25, seed=5)
    successes = 0
    for seed in range(6):
        res = fednc.fednc_round(clients, [0.25] * 4, prev, cfg,
                                jax.random.PRNGKey(seed), channel=chan)
        successes += int(res.decoded)
    assert successes >= 3


def test_multihop_recode_roundtrip():
    clients = _clients(3, seed=9)
    prev = clients[0]
    cfg = FedNCConfig(s=8)
    chan = MultiHopChannel(eta=4, seed=2)
    res = fednc.fednc_round(clients, [1, 1, 1], prev, cfg,
                            jax.random.PRNGKey(1), channel=chan)
    if res.decoded:
        ref = fednc.fedavg_round(clients, [1, 1, 1], prev)
        np.testing.assert_array_equal(
            np.asarray(res.global_params["w"]),
            np.asarray(ref.global_params["w"]))


def test_strategies_blind_box():
    from repro.federation.server import FedAvgStrategy, FedNCStrategy
    clients = _clients(5, seed=11)
    weights = [0.2] * 5
    prev = clients[0]
    rng = np.random.default_rng(0)
    # FedNC through a blind box with budget=K decodes w.h.p. (s=8) and
    # equals the all-client FedAvg aggregate
    st_nc = FedNCStrategy(config=FedNCConfig(s=8),
                          channel=BlindBoxChannel(budget=5))
    res = st_nc.aggregate(clients, weights, prev, rng)
    if res.decoded:
        ref = fednc.fedavg_round(clients, weights, prev)
        np.testing.assert_array_equal(
            np.asarray(res.global_params["w"]),
            np.asarray(ref.global_params["w"]))
    # FedAvg through the same blind box usually hears < 5 distinct
    st_avg = FedAvgStrategy(channel=BlindBoxChannel(budget=5))
    res2 = st_avg.aggregate(clients, weights, prev,
                            np.random.default_rng(1))
    assert res2.report.distinct_sources <= 5
