"""Mesh-level FedNC collective (core.dist): coded mean == plain mean.

Runs in a subprocess with 8 forced host devices so the main pytest
process keeps its single-device view (the dryrun-only 512-device trick
must NOT leak into tests)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import dist

devs = np.array(jax.devices()[:8]).reshape(8)
mesh = Mesh(devs, ("data",))
key = jax.random.PRNGKey(0)
tree = {"w": jax.random.normal(key, (8, 33, 5)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 7))}
out = {}
for mode in ("naive", "blocked", "psum"):
    f = dist.make_fednc_mean(mesh, axis="data", mode=mode)
    with mesh:
        res = jax.jit(f)(tree, jax.random.PRNGKey(7))
    err = 0.0
    for k, v in tree.items():
        want = jnp.broadcast_to(jnp.mean(v, 0, keepdims=True), v.shape)
        err = max(err, float(jnp.abs(res[k] - want).max()))
    out[mode] = err
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_fednc_mesh_mean_all_modes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    errs = json.loads(line.split(" ", 1)[1])
    assert errs["psum"] < 1e-6
    assert errs["naive"] < 1e-4
    assert errs["blocked"] < 1e-4


def test_aggregate_gradients_single_device():
    """The pjit formulation used by train_step: all three modes return
    the client mean (float-field decode is exact up to fp32 solve)."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.steps import aggregate_gradients, float_inv
    key = jax.random.PRNGKey(0)
    K = 8
    grads = {"a": jax.random.normal(key, (K, 13, 3)),
             "b": jax.random.normal(jax.random.fold_in(key, 2), (K, 5))}
    want = {k: jnp.mean(v, 0) for k, v in grads.items()}
    for mode in ("plain", "fednc_naive", "fednc_blocked"):
        got = aggregate_gradients(grads, jax.random.PRNGKey(3), K, mode)
        for k in grads:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       rtol=5e-4, atol=5e-5)
    # float_inv really inverts
    A = jax.random.normal(jax.random.PRNGKey(9), (16, 16))
    np.testing.assert_allclose(np.asarray(float_inv(A) @ A),
                               np.eye(16), atol=1e-4)
