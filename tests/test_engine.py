"""CodingEngine: chunked/lane-packed/multi-device pipeline vs oracles.

The engine must be *bit-exact* against the seed's reference path
(table-based jnp matmul + monolithic Gaussian elimination) for every
byte-aligned field size, every chunking configuration, and every
registered kernel — GF arithmetic has no rounding, so any mismatch is
a real bug.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packets as pkt, rlnc
from repro.core.gf import ge_solve, get_field, rank as gf_rank
from repro.engine import (CodingEngine, EngineConfig, get_engine,
                          incremental_select, register_kernel,
                          resolve_kernel)
from repro.kernels import ref


# ---------------------------------------------------------------------------
# round(): encode -> chunked decode, bit-exact vs the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 4, 8])
@pytest.mark.parametrize("L,chunk_l", [
    (1000, 256),     # several whole chunks + remainder
    (2049, 512),     # odd L, not divisible by the chunk size
    (37, 0),         # chunking disabled
    (500, 4096),     # single partial chunk
])
def test_round_bit_exact_vs_oracle(s, L, chunk_l):
    f = get_field(s)
    K = 6
    kp, kk = jax.random.split(jax.random.PRNGKey(s * 1000 + L))
    P = f.random_elements(kp, (K, L))
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed",
                                    chunk_l=chunk_l))
    out = eng.round(P, kk)
    # oracle: same coding matrix, table matmul, monolithic GE
    A = eng.coding_matrix(kk, K, K)
    ok_ref, X_ref = ge_solve(f, A, ref.gf_matmul_ref(A, P, s))
    assert out.ok == bool(ok_ref)
    if out.ok:
        np.testing.assert_array_equal(np.asarray(out.packets),
                                      np.asarray(X_ref))
        np.testing.assert_array_equal(np.asarray(out.packets),
                                      np.asarray(P))


def test_round_n_gt_K_extra_tuples_chunked():
    s, K, L = 8, 5, 777
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(3), (K, L))
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed",
                                    chunk_l=128, extra_tuples=3))
    out = eng.round(P, jax.random.PRNGKey(7))
    assert out.ok
    np.testing.assert_array_equal(np.asarray(out.packets), np.asarray(P))


def test_decode_n_gt_K_with_dependent_rows():
    """Duplicated/combined rows must be skipped by the on-device
    selector, and decode still recovers P exactly."""
    s, K, L = 8, 5, 260
    f = get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    P = f.random_elements(k1, (K, L))
    A = f.random_elements(k2, (K, K))
    if int(gf_rank(f, A)) < K:
        pytest.skip("unlucky singular draw")
    C = ref.gf_matmul_ref(A, P, s)
    # prepend a duplicate and a GF-linear combination of rows 0 and 1
    combo_a = f.add(A[0], f.mul(jnp.uint8(3), A[1]))[None]
    combo_c = f.add(C[0], f.mul(jnp.uint8(3), C[1]))[None]
    batch = rlnc.EncodedBatch(
        A=jnp.concatenate([A[:1], combo_a, A], 0),
        C=jnp.concatenate([C[:1], combo_c, C], 0),
    )
    eng = CodingEngine(EngineConfig(s=s, kernel="jnp", chunk_l=64))
    ok, X = eng.decode(batch)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(X), np.asarray(P))


def test_decode_rank_deficient_fails():
    s, K, L = 8, 4, 40
    f = get_field(s)
    P = f.random_elements(jax.random.PRNGKey(0), (K, L))
    A = jnp.tile(f.random_elements(jax.random.PRNGKey(1), (1, K)), (K + 2, 1))
    C = ref.gf_matmul_ref(A, P, s)
    eng = get_engine(EngineConfig(s=s, kernel="jnp"))
    ok, X = eng.decode(rlnc.EncodedBatch(A=A, C=C))
    assert not ok and X is None


# ---------------------------------------------------------------------------
# kernels: lane-packed vs unpacked equivalence through the registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 4, 8])
@pytest.mark.parametrize("kernel", ["jnp_clmul", "jnp_packed",
                                    "pallas_packed"])
def test_kernel_variants_match_table_oracle(s, kernel, subtests=None):
    f = get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(s))
    for (n, K, L) in [(1, 1, 1), (5, 4, 17), (7, 6, 2051)]:
        A = f.random_elements(k1, (n, K))
        P = f.random_elements(k2, (K, L))
        got = resolve_kernel(kernel)[1](A, P, s=s)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.gf_matmul_ref(A, P, s)),
            err_msg=f"{kernel} s={s} shape={(n, K, L)}")


def test_lane_packed_equals_unpacked_chunked():
    """Packed and unpacked kernels agree element-for-element through
    the chunked executor, including the pad-and-unpad path."""
    s, K, L = 8, 9, 3000   # L % 4 == 0 but L % chunk != 0
    f = get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    A = f.random_elements(k1, (K, K))
    P = f.random_elements(k2, (K, L))
    packed = CodingEngine(EngineConfig(s=s, kernel="jnp_packed",
                                       chunk_l=1024)).matmul(A, P)
    unpacked = CodingEngine(EngineConfig(s=s, kernel="jnp_clmul",
                                         chunk_l=512)).matmul(A, P)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(unpacked))


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown kernel"):
        resolve_kernel("no_such_backend")
    with pytest.raises(ValueError, match="already registered"):
        register_kernel("jnp", lambda A, P, s: A)
    with pytest.raises(ValueError, match="reserved"):
        register_kernel("auto", lambda A, P, s: A)


# ---------------------------------------------------------------------------
# selector: jit-safe incremental GE == rank oracle / legacy greedy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [2, 8])
def test_incremental_select_matches_rank(s):
    f = get_field(s)
    for seed in range(10):
        A = f.random_elements(jax.random.PRNGKey(seed), (9, 5))
        ok, idx, count = incremental_select(A, s)
        assert int(count) == min(int(gf_rank(f, A)), 5)
        assert bool(ok) == (int(gf_rank(f, A)) == 5)
        if bool(ok):
            # the selected rows really are independent
            assert int(gf_rank(f, A[idx])) == 5


def test_incremental_select_is_jit_safe():
    """The selector must trace (no host sync inside) — the seed's
    numpy greedy loop could not."""
    s = 8
    f = get_field(s)
    A = f.random_elements(jax.random.PRNGKey(0), (8, 4))

    @jax.jit
    def sel(A):
        from repro.engine.select import incremental_select as isel
        return isel(A, s)

    ok, idx, count = sel(A)
    assert bool(ok) == (int(gf_rank(f, A)) == 4)


# ---------------------------------------------------------------------------
# batched packetization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 4, 8])
def test_batched_packetize_matches_per_client(s):
    trees = [{"w": jax.random.normal(jax.random.PRNGKey(i), (3, 5)),
              "b": (jnp.arange(4, dtype=jnp.int32) * i)}
             for i in range(4)]
    P, spec = pkt.pytrees_to_packets(trees, s=s)
    rows = [pkt.pytree_to_packet(t, s=s)[0] for t in trees]
    np.testing.assert_array_equal(np.asarray(P),
                                  np.asarray(jnp.stack(rows)))
    back = pkt.packets_to_pytrees(P, spec)
    for i, t in enumerate(trees):
        for name in t:
            np.testing.assert_array_equal(np.asarray(back[name][i]),
                                          np.asarray(t[name]))


# ---------------------------------------------------------------------------
# multi-device: shard_map lane sharding (subprocess, like test_dist)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.engine import CodingEngine, EngineConfig
from repro.core.gf import get_field
from repro.kernels import ref

mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("data",))
s, K, L = 8, 6, 4096 + 37          # odd L exercises the pad path
f = get_field(s)
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
A = f.random_elements(k1, (K, K))
P = f.random_elements(k2, (K, L))
eng = CodingEngine(EngineConfig(s=s, kernel="jnp_packed", chunk_l=1024,
                                lane_axis="data"), mesh=mesh)
np.testing.assert_array_equal(np.asarray(eng.matmul(A, P)),
                              np.asarray(ref.gf_matmul_ref(A, P, s)))
out = eng.round(P, jax.random.PRNGKey(5))
assert out.ok
np.testing.assert_array_equal(np.asarray(out.packets), np.asarray(P))
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_lane_sharded_engine_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED_OK" in proc.stdout
