"""repro.analysis: rule fixtures, suppressions, contract failures.

Each FNC rule gets doctored source that must fire at the expected
line (and a near-miss that must stay clean), the suppression marker
is exercised both honored and ignored, the contract checker is fed
deliberately broken registry entries (wrong dtype, shape drift,
orphaned seeded kernel), and the whole repo is asserted to lint
clean — the same gate ``python -m repro.analysis`` enforces in CI.
"""
import json
import pathlib
import textwrap

import pytest

from repro.analysis import (ANALYSIS_SCHEMA, analyze_source,
                            check_kernel_contracts,
                            check_registry_docstring, run_analysis)
from repro.analysis.__main__ import main as analysis_main
from repro.engine import registry

ROOT = pathlib.Path(__file__).resolve().parent.parent


def rules_at(rel, source):
    """[(rule, line)] of kept findings for one fixture module."""
    findings, _ = analyze_source(rel, textwrap.dedent(source))
    return [(f.rule, f.line) for f in findings]


# ---------------------------------------------------------------------------
# FNC001 raw-clock
# ---------------------------------------------------------------------------

def test_fnc001_fires_on_raw_clock():
    src = """\
    import time
    t0 = time.perf_counter()
    """
    assert rules_at("src/repro/engine/x.py", src) == [("FNC001", 2)]


def test_fnc001_sees_through_import_aliases():
    src = """\
    from time import perf_counter as pc
    t0 = pc()
    """
    assert rules_at("benchmarks/bench_x.py", src) == [("FNC001", 2)]


def test_fnc001_exempts_obs():
    src = """\
    import time
    t0 = time.perf_counter()
    """
    assert rules_at("src/repro/obs/trace.py", src) == []


# ---------------------------------------------------------------------------
# FNC002 unfenced-timing
# ---------------------------------------------------------------------------

_TIMED = """\
import jax.numpy as jnp
from repro import obs

def bench(A, B):
    with obs.timed("matmul") as sw:
        C = jnp.dot(A, B)
    {tail}
    return C
"""


def test_fnc002_fires_on_unfenced_region():
    src = _TIMED.format(tail="")
    assert rules_at("benchmarks/bench_x.py", src) == [("FNC002", 5)]


def test_fnc002_clean_when_fenced():
    src = _TIMED.replace("C = jnp.dot(A, B)",
                         "C = sw.fence(jnp.dot(A, B))").format(tail="")
    assert rules_at("benchmarks/bench_x.py", src) == []


def test_fnc002_clean_when_region_does_no_jax_work():
    src = """\
    from repro import obs

    def bench(xs):
        with obs.timed("sort") as sw:
            out = sorted(xs)
        return out
    """
    assert rules_at("benchmarks/bench_x.py", src) == []


# ---------------------------------------------------------------------------
# FNC003 tracer-leak
# ---------------------------------------------------------------------------

def test_fnc003_fires_on_host_cast_in_jit():
    src = """\
    import jax

    @jax.jit
    def f(x):
        return float(x) + 1.0
    """
    assert rules_at("src/repro/core/x.py", src) == [("FNC003", 5)]


def test_fnc003_fires_on_python_branch_and_item():
    src = """\
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x.item()
        return x
    """
    assert rules_at("src/repro/core/x.py", src) == [
        ("FNC003", 5), ("FNC003", 6)]


def test_fnc003_fires_in_helper_reachable_from_jit():
    src = """\
    import jax
    import numpy as np

    def helper(x):
        return np.asarray(x)

    @jax.jit
    def f(x):
        return helper(x)
    """
    assert rules_at("src/repro/core/x.py", src) == [("FNC003", 5)]


def test_fnc003_static_argnames_exempt():
    src = """\
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("s",))
    def f(x, *, s):
        if s == 1:
            return x
        return x + s
    """
    assert rules_at("src/repro/core/x.py", src) == []


def test_fnc003_shape_control_flow_is_static():
    src = """\
    import jax

    @jax.jit
    def f(x):
        n, k = x.shape
        if k > 4:
            return x[:, :4]
        return x
    """
    assert rules_at("src/repro/core/x.py", src) == []


def test_fnc003_plain_functions_not_flagged():
    src = """\
    def f(x):
        return float(x)
    """
    assert rules_at("src/repro/core/x.py", src) == []


# ---------------------------------------------------------------------------
# FNC004 unseeded-rng
# ---------------------------------------------------------------------------

def test_fnc004_fires_in_scoped_paths():
    src = """\
    import random
    import numpy as np
    a = np.random.rand(3)
    b = random.random()
    """
    assert rules_at("src/repro/sim/x.py", src) == [
        ("FNC004", 3), ("FNC004", 4)]


def test_fnc004_seeded_generator_is_sanctioned():
    src = """\
    import numpy as np
    rng = np.random.default_rng(0)
    a = rng.random(3)
    b = np.random.Generator(np.random.PCG64(1))
    """
    assert rules_at("src/repro/serve/x.py", src) == []


def test_fnc004_out_of_scope_paths_ignored():
    src = """\
    import numpy as np
    a = np.random.rand(3)
    """
    assert rules_at("src/repro/data/x.py", src) == []


# ---------------------------------------------------------------------------
# FNC005 dtype-discipline
# ---------------------------------------------------------------------------

def test_fnc005_fires_on_promoted_dtypes():
    src = """\
    import jax.numpy as jnp

    def k(A):
        f = A.astype(jnp.float32)
        z = jnp.zeros((2, 2), jnp.float16)
        return f, z
    """
    assert rules_at("src/repro/kernels/gf_custom.py", src) == [
        ("FNC005", 4), ("FNC005", 5)]


def test_fnc005_resolves_module_dtype_constants():
    src = """\
    import jax.numpy as jnp
    _ACC_DTYPE = jnp.float32

    def k(A):
        return A.astype(_ACC_DTYPE)
    """
    assert rules_at("src/repro/kernels/gf_custom.py", src) == [
        ("FNC005", 5)]


def test_fnc005_field_dtypes_clean_and_scope_limited():
    src = """\
    import jax.numpy as jnp

    def k(A):
        packed = A.astype(jnp.int32)
        return jnp.zeros((2, 2), dtype=jnp.uint8), packed
    """
    assert rules_at("src/repro/kernels/gf_custom.py", src) == []
    # float math is the whole point outside the GF modules
    bad = "import jax.numpy as jnp\nx = jnp.zeros((2,), jnp.float32)\n"
    assert rules_at("src/repro/kernels/flash_attention.py", bad) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_honored_and_audited():
    src = ("import time\n"
           "t0 = time.time()  # fednc: ignore[FNC001] epoch anchor\n")
    findings, suppressed = analyze_source("src/repro/core/x.py", src)
    assert findings == []
    (s,) = suppressed
    assert (s.rule, s.line, s.justification) == (
        "FNC001", 2, "epoch anchor")


def test_suppression_must_name_the_rule():
    src = ("import time\n"
           "t0 = time.time()  # fednc: ignore[FNC002] wrong id\n")
    findings, suppressed = analyze_source("src/repro/core/x.py", src)
    assert [f.rule for f in findings] == ["FNC001"]
    assert suppressed == []


# ---------------------------------------------------------------------------
# contract checker: doctored registry entries
# ---------------------------------------------------------------------------

def _register_temp(name, fn, seeded=False):
    registry.register_kernel(name, fn, seeded=seeded)
    return name


def test_contract_wrong_dtype_detected():
    import jax.numpy as jnp

    name = _register_temp(
        "ctr_bad_dtype",
        lambda A, P, *, s: jnp.zeros(
            (A.shape[0], P.shape[1]), jnp.int32))
    try:
        violations, summary = check_kernel_contracts(kernels=[name])
        assert violations and all(v.rule == "CTR001" for v in violations)
        assert any("dtype" in v.message for v in violations)
        assert summary["violations"]
    finally:
        registry.unregister_kernel(name)


def test_contract_shape_drift_detected():
    import jax.numpy as jnp

    name = _register_temp(
        "ctr_bad_shape",
        lambda A, P, *, s: jnp.zeros(
            (A.shape[0], P.shape[1] + 1), jnp.uint8))
    try:
        violations, _ = check_kernel_contracts(kernels=[name])
        assert violations and all(v.rule == "CTR001" for v in violations)
        assert any("shape" in v.message for v in violations)
    finally:
        registry.unregister_kernel(name)


def test_contract_orphan_seeded_kernel_detected():
    import jax.numpy as jnp

    name = _register_temp(
        "ctr_orphan_seeded",
        lambda seeds, P, *, s: jnp.zeros(
            (seeds.shape[0], P.shape[1]), jnp.uint8),
        seeded=True)
    try:
        violations, _ = check_kernel_contracts(kernels=[name])
        assert any(v.rule == "CTR002"
                   and "sibling" in v.message for v in violations)
    finally:
        registry.unregister_kernel(name)


def test_contract_seeded_suffix_required():
    import jax.numpy as jnp

    name = _register_temp(
        "ctr_sneaky",
        lambda seeds, P, *, s: jnp.zeros(
            (seeds.shape[0], P.shape[1]), jnp.uint8),
        seeded=True)
    try:
        violations, _ = check_kernel_contracts(kernels=[name])
        assert any(v.rule == "CTR002" and "suffix" in v.message
                   for v in violations)
    finally:
        registry.unregister_kernel(name)


def test_contract_pass_leaves_no_tracer_residue():
    """eval_shape-ing the registry must not poison process caches.

    get_field's lru_cache fills on first use; if that first use is
    the contract checker's abstract trace, the cached tables must
    still be concrete arrays — a leaked tracer here breaks every
    later real decode in the process."""
    import jax.numpy as jnp

    from repro.core.gf import get_field

    get_field.cache_clear()
    violations, _ = check_kernel_contracts()
    assert violations == []
    A = jnp.array([[2]], dtype=jnp.uint8)
    P = jnp.array([[7]], dtype=jnp.uint8)
    assert int(registry.gf_matmul(A, P, s=8, kernel="jnp")[0, 0]) == 14


def test_registry_docstring_drift_detected(monkeypatch):
    doc = registry.__doc__
    assert check_registry_docstring() == []      # in sync today
    monkeypatch.setattr(
        registry, "__doc__",
        doc.replace("``jnp_packed``", "``jnp_unpacked``"))
    findings = check_registry_docstring()
    assert {f.rule for f in findings} == {"CTR003"}
    assert any("jnp_packed" in f.message for f in findings)
    assert any("jnp_unpacked" in f.message for f in findings)


def test_unregister_kernel_guards():
    with pytest.raises(ValueError, match="reserved alias"):
        registry.unregister_kernel("auto")
    with pytest.raises(ValueError, match="not registered"):
        registry.unregister_kernel("never_was")


# ---------------------------------------------------------------------------
# whole-repo gate + CLI
# ---------------------------------------------------------------------------

def test_repo_lints_clean_with_empty_baseline():
    report = run_analysis(ROOT)
    assert report["schema"] == ANALYSIS_SCHEMA
    assert report["findings"] == []
    assert report["ok"] is True
    assert report["files_scanned"] > 50
    # every honored suppression must carry a justification (auditable
    # empty baseline: zero findings, zero unexplained ignores)
    assert all(s["justification"] for s in report["suppressed"])
    assert report["contracts"]["points_checked"] > 0
    assert "jnp_packed_seeded" in report["contracts"]["kernels"]


def test_cli_reports_failure_and_writes_json(tmp_path, capsys):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "x.py").write_text("import time\nt = time.time()\n")
    out = tmp_path / "r.json"
    rc = analysis_main(["--root", str(tmp_path), "--json", str(out),
                        "--no-contracts"])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["ok"] is False
    assert report["counts_by_rule"] == {"FNC001": 1}
    assert "FNC001" in capsys.readouterr().err


def test_cli_ok_on_clean_tree(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "x.py").write_text("x = 1\n")
    rc = analysis_main(["--root", str(tmp_path), "--no-contracts"])
    assert rc == 0
