"""GF(2^s) field properties (hypothesis) + Gaussian elimination."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import gf

FIELDS = [1, 2, 3, 4, 8]


@pytest.mark.parametrize("s", FIELDS)
def test_exp_log_inverse_bijection(s):
    f = gf.get_field(s)
    q = f.q
    elems = jnp.arange(1, q, dtype=jnp.uint8)
    # log then exp is identity on nonzero elements
    back = jnp.take(f.exp, jnp.take(f.log, elems.astype(jnp.int32)))
    assert (back == elems).all()


@settings(max_examples=25, deadline=None)
@given(s=st.sampled_from(FIELDS), seed=st.integers(0, 2**16))
def test_field_axioms(s, seed):
    f = gf.get_field(s)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = f.random_elements(k1, (64,))
    b = f.random_elements(k2, (64,))
    c = f.random_elements(k3, (64,))
    # commutativity / associativity of mul
    assert (f.mul(a, b) == f.mul(b, a)).all()
    assert (f.mul(f.mul(a, b), c) == f.mul(a, f.mul(b, c))).all()
    # distributivity over xor-addition
    assert (f.mul(a, f.add(b, c)) == f.add(f.mul(a, b), f.mul(a, c))).all()
    # multiplicative identity & zero
    assert (f.mul(a, jnp.uint8(1)) == a).all()
    assert (f.mul(a, jnp.uint8(0)) == 0).all()
    # inverse on non-zeros
    nz = a[a != 0]
    if nz.size:
        assert (f.mul(nz, f.inv(nz)) == 1).all()


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([2, 4, 8]), K=st.integers(2, 12),
       L=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_ge_solve_roundtrip(s, K, L, seed):
    f = gf.get_field(s)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    A = f.random_elements(k1, (K, K))
    P = f.random_elements(k2, (K, L))
    C = f.matmul(A, P)
    ok, X = gf.ge_solve(f, A, C)
    full_rank = int(gf.rank(f, A)) == K
    assert bool(ok) == full_rank
    if full_rank:
        assert (X == P).all()


def test_rank_properties():
    f = gf.get_field(8)
    key = jax.random.PRNGKey(0)
    A = f.random_elements(key, (6, 6))
    r = int(gf.rank(f, A))
    assert 0 <= r <= 6
    # duplicating a row cannot increase rank and forces rank < n
    A2 = A.at[3].set(A[0])
    assert int(gf.rank(f, A2)) <= 5
    # identity has full rank
    assert int(gf.rank(f, jnp.eye(7, dtype=jnp.uint8))) == 7
    # zero matrix has rank 0
    assert int(gf.rank(f, jnp.zeros((4, 4), jnp.uint8))) == 0


def test_invert():
    f = gf.get_field(8)
    key = jax.random.PRNGKey(3)
    A = f.random_elements(key, (8, 8))
    ok, Ainv = gf.invert(f, A)
    if bool(ok):
        assert (f.matmul(A, Ainv) == jnp.eye(8, dtype=jnp.uint8)).all()
