"""Shared test configuration.

jax.clear_caches() after every module: the suite jit-compiles hundreds
of distinct shapes (hypothesis sweeps + interpret-mode Pallas kernels);
without clearing, the CPU-client compilation cache grows unboundedly
and eventually corrupts/aborts the runtime mid-suite.

NOTE: no XLA_FLAGS here — tests must see the real single-device view
(the 512-device override belongs to repro.launch.dryrun ONLY).
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
