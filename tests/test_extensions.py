"""Beyond-baseline FedNC features: hierarchical edge mixing (paper
§III's suggested deployment), sparse RLNC, and quantized packets
(paper ref [22])."""
import jax
import numpy as np
import pytest

from repro.core import fednc, hierarchy
from repro.core.channel import ErasureChannel
from repro.core.fednc import FedNCConfig
from repro.core.gf import get_field
from repro.core.rlnc import sparse_coding_matrix


def _clients(n, shape=(16, 3), seed=0):
    key = jax.random.PRNGKey(seed)
    return [{"w": jax.random.normal(jax.random.fold_in(key, i), shape)}
            for i in range(n)]


# ---------------------------------------------------------------------------
# hierarchical FedNC
# ---------------------------------------------------------------------------

def test_hierarchical_equals_fedavg():
    clients = _clients(6)
    weights = [1 / 6] * 6
    prev = clients[0]
    res = hierarchy.hierarchical_fednc_round(
        clients, weights, prev, FedNCConfig(s=8), jax.random.PRNGKey(0),
        num_edges=3)
    if res.decoded:
        ref = fednc.fedavg_round(clients, weights, prev)
        np.testing.assert_array_equal(
            np.asarray(res.global_params["w"]),
            np.asarray(ref.global_params["w"]))


def test_hierarchical_edge_coding_matrix_is_block_structured():
    P = get_field(8).random_elements(jax.random.PRNGKey(1), (6, 50))
    edges = hierarchy.partition_edges(6, 2)
    b = hierarchy.edge_encode(P, edges[0], 6, 3, FedNCConfig(s=8),
                              jax.random.PRNGKey(2))
    A = np.asarray(b.A)
    # columns outside the edge's clients are zero
    outside = [c for c in range(6) if c not in edges[0].client_ids]
    assert (A[:, outside] == 0).all()
    # coded payload is consistent: C = A · P over the global index space
    C_ref = get_field(8).matmul(b.A, P)
    np.testing.assert_array_equal(np.asarray(b.C), np.asarray(C_ref))


def test_hierarchical_spares_fix_wan_erasure():
    clients = _clients(6, seed=4)
    weights = [1 / 6] * 6
    prev = clients[0]
    ok_with_spares = 0
    for seed in range(8):
        res = hierarchy.hierarchical_fednc_round(
            clients, weights, prev, FedNCConfig(s=8),
            jax.random.PRNGKey(seed), num_edges=2, spare_per_edge=2,
            wan_channel=ErasureChannel(p_erase=0.2, seed=seed))
        ok_with_spares += int(res.decoded)
    assert ok_with_spares >= 5


# ---------------------------------------------------------------------------
# sparse RLNC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", [0.3, 0.7])
def test_sparse_matrix_properties(density):
    A = sparse_coding_matrix(jax.random.PRNGKey(0), 20, 10, 8,
                             density=density)
    A = np.asarray(A)
    # at least one nonzero per row
    assert (A != 0).any(axis=1).all()
    frac = (A != 0).mean()
    assert density - 0.2 < frac < density + 0.25


def test_sparse_round_decodes_or_skips_cleanly():
    clients = _clients(5, seed=7)
    prev = clients[0]
    cfg = FedNCConfig(s=8, coding_density=0.6)
    res = fednc.fednc_round(clients, [0.2] * 5, prev, cfg,
                            jax.random.PRNGKey(3))
    if res.decoded:
        ref = fednc.fedavg_round(clients, [0.2] * 5, prev)
        np.testing.assert_array_equal(
            np.asarray(res.global_params["w"]),
            np.asarray(ref.global_params["w"]))
    else:
        assert res.global_params is prev


# ---------------------------------------------------------------------------
# quantized packets (paper ref [22])
# ---------------------------------------------------------------------------

def test_quantized_round_close_to_fedavg():
    clients = _clients(4, seed=9)
    prev = clients[0]
    cfg = FedNCConfig(s=8, quantize_bits=8)
    res = fednc.fednc_round(clients, [0.25] * 4, prev, cfg,
                            jax.random.PRNGKey(5))
    assert res.decoded
    ref = fednc.fedavg_round(clients, [0.25] * 4, prev)
    got = np.asarray(res.global_params["w"], np.float32)
    want = np.asarray(ref.global_params["w"], np.float32)
    # int8 quantization error bound: ~ range/255 per client, averaged
    assert np.max(np.abs(got - want)) < 0.05
    # and the quantized upload is 4x smaller
    q, _ = fednc.encode_clients(clients, cfg, jax.random.PRNGKey(6))[0:2]
    full = fednc.encode_clients(clients, FedNCConfig(s=8),
                                jax.random.PRNGKey(6))[0]
    assert q.C.shape[1] * 4 == full.C.shape[1]
