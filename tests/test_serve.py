"""Multi-tenant decode server: correctness under adversarial traffic.

The serving contract: any interleaving of many jobs' packets — any
per-job arrival order, duplicate/dependent rows, dropped rows
(including enough drops to starve a job below rank K), mixed seeded +
materialized wire formats, more jobs than slots — decodes every
completable job bit-exactly to the same payload, at the same per-job
completion arrival count, as an isolated per-job `StreamDecoder`.
Scheduler ticks must never mix job state, and replaying a trace under
ANY tick size / slot count / dispatch mode must give identical
results (only wall-clock changes).

Property-tested with hypothesis when installed, deterministic sweep
otherwise (the container ships without it; pip install -r
requirements-dev.txt for the full search).  The recorded fixture
``tests/data/serve_trace.json`` pins completion arrival counts and
payload digests against regressions.
"""
import pathlib
import runpy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gf import get_field
from repro.core.seeds import expand_rows_jit
from repro.engine import StreamDecoder
from repro.serve import (DecodeServer, FifoScheduler, ServeJob,
                         ServeTrace, payload_digest,
                         poisson_multitenant_trace, serve_trace)

ROOT = pathlib.Path(__file__).resolve().parent.parent
DATA = pathlib.Path(__file__).resolve().parent / "data"
S = 8


def _sig(report):
    """The deterministic completion signature of a served trace."""
    return [(c.job, c.arrivals, c.payload_sha)
            for c in report.completions]


# ---------------------------------------------------------------------------
# fuzzed interleavings vs the per-job StreamDecoder reference
# ---------------------------------------------------------------------------

def _fuzz_trace(n_jobs, case_seed, *, dup=0.0, drop=0.0):
    """An adversarial hand-built trace + per-job ground truth.

    Per-job K/L/wire-format are random; `dup` re-sends (dependent
    rows), `drop` erases packets (possibly starving a job below rank
    K); the global interleaving is a uniform shuffle.  Returns
    ``(trace, truth P per job, (seeds, rows, C) per job)``.
    """
    rng = np.random.default_rng(case_seed)
    field = get_field(S)
    metas, per_job, truth = [], [], []
    for j in range(n_jobs):
        k, l = int(rng.integers(2, 7)), int(rng.integers(1, 20))
        n = k + int(rng.integers(1, 5))
        seeds_j = rng.integers(0, 1 << 32, n).astype(np.uint32)
        if dup and n > 1:
            di = rng.random(n) < dup
            di[0] = False
            idx = np.arange(n)
            idx[di] -= 1
            seeds_j = seeds_j[idx]
        P = np.asarray(field.random_elements(
            jax.random.PRNGKey(case_seed * 131 + j), (k, l)))
        A = np.asarray(expand_rows_jit(seeds_j, k, S))
        C = np.asarray(field.matmul(jnp.asarray(A), jnp.asarray(P)))
        if drop and n > 1:
            keep = rng.random(n) > drop
            keep[int(rng.integers(n))] = True
            seeds_j, A, C = seeds_j[keep], A[keep], C[keep]
        metas.append(ServeJob(job=j, K=k, L=l,
                              seeded=bool(rng.random() < 0.5),
                              t_start=0.0))
        per_job.append((seeds_j, A, C))
        truth.append(P)

    job_seq = np.repeat(np.arange(n_jobs),
                        [len(p[0]) for p in per_job])
    rng.shuffle(job_seq)
    G = len(job_seq)
    max_l = max(m.L for m in metas)
    row_seeds = np.zeros(G, np.uint32)
    payloads = np.zeros((G, max_l), np.uint8)
    ptr = np.zeros(n_jobs, int)
    for i, j in enumerate(job_seq):
        p = ptr[j]
        ptr[j] += 1
        row_seeds[i] = per_job[j][0][p]
        payloads[i, : metas[j].L] = per_job[j][2][p]
    trace = ServeTrace(s=S, jobs=metas,
                       times=np.arange(G, dtype=np.float64),
                       job_of=job_seq.astype(np.int64),
                       row_seeds=row_seeds, payloads=payloads)
    return trace, truth, per_job


def _serve_fuzz_case(n_jobs, slots, g_tick, case_seed, dup, drop):
    trace, truth, per_job = _fuzz_trace(
        n_jobs, case_seed, dup=0.3 if dup else 0.0,
        drop=0.25 if drop else 0.0)
    rep = serve_trace(trace, slots=slots, g_tick=g_tick, batched=True)
    by_job = {c.job: c for c in rep.completions}

    # the reference: each job decoded alone, same per-job order
    for j, meta in enumerate(trace.jobs):
        seeds_j, A, C = per_job[j]
        dec = StreamDecoder(K=meta.K, L=meta.L, s=S)
        if len(seeds_j):
            if meta.seeded:
                dec.ingest(jnp.asarray(seeds_j), jnp.asarray(C))
            else:
                dec.ingest(jnp.asarray(A), jnp.asarray(C))
        ok, P_hat = dec.decode()
        if j in by_job:
            assert ok, f"job {j}: server decoded, reference did not"
            c = by_job[j]
            assert c.arrivals == dec.decoded_at
            assert c.payload_sha == payload_digest(P_hat)
            assert c.payload_sha == payload_digest(truth[j])
        else:
            assert not ok, (
                f"job {j}: reference decoded, server did not")

    # sequential dispatch must be byte-identical to batched
    rep_seq = serve_trace(trace, slots=slots, g_tick=g_tick,
                          batched=False)
    assert _sig(rep) == _sig(rep_seq)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n_jobs=st.integers(1, 6), slots=st.integers(1, 4),
           g_tick=st.integers(1, 6), case_seed=st.integers(0, 2**30),
           dup=st.booleans(), drop=st.booleans())
    def test_serve_interleaving_property(n_jobs, slots, g_tick,
                                         case_seed, dup, drop):
        _serve_fuzz_case(n_jobs, slots, g_tick, case_seed, dup, drop)
else:
    @pytest.mark.parametrize("n_jobs,slots,g_tick,case_seed,dup,drop", [
        (1, 1, 1, 0, False, False),
        (4, 2, 3, 1, True, False),
        (6, 4, 2, 2, False, True),
        (5, 3, 6, 3, True, True),
        (6, 1, 4, 4, True, False),
        (3, 4, 1, 5, False, True),
        (2, 2, 5, 6, True, True),
    ])
    def test_serve_interleaving_cases(n_jobs, slots, g_tick,
                                      case_seed, dup, drop):
        """Deterministic sweep standing in when hypothesis is absent
        (pip install -r requirements-dev.txt for the full search)."""
        _serve_fuzz_case(n_jobs, slots, g_tick, case_seed, dup, drop)


# ---------------------------------------------------------------------------
# scheduler mechanics + slot isolation
# ---------------------------------------------------------------------------

def test_scheduler_front_packed_fifo():
    sched = FifoScheduler(slots=2, K=4, L=6, g_tick=2)
    for i in range(3):
        sched.enqueue(0, seed=i, payload=np.full(6, i, np.uint8))
    sched.enqueue(1, seed=99, payload=np.arange(6, dtype=np.uint8),
                  row=np.array([1, 2, 3], np.uint8))
    assert sched.pending == 4 and sched.max_depth == 3
    rows, seeds, use, valid, C = sched.next_block()
    assert rows.shape == (2, 2, 4) and C.shape == (2, 2, 6)
    # slot 0: FIFO order, both positions valid, seeded format
    assert seeds[0].tolist() == [0, 1] and use[0].all()
    assert valid[0].tolist() == [True, True]
    # slot 1: one packet front-packed, materialized row zero-padded to K
    assert valid[1].tolist() == [True, False]
    assert not use[1, 0] and rows[1, 0].tolist() == [1, 2, 3, 0]
    # leftover stays queued for the next tick
    assert sched.pending == 1
    _, seeds2, _, valid2, _ = sched.next_block()
    assert seeds2[0, 0] == 2 and valid2.sum() == 1
    assert sched.next_block() is None


def test_ticks_do_not_cross_contaminate_slots():
    """Traffic for one job must leave every other slot's basis state
    untouched, bit for bit."""
    k, l = 4, 8
    field = get_field(S)
    srv = DecodeServer(slots=3, K=k, L=l, s=S, g_tick=2)
    for j in range(3):
        srv.submit(j, k, l)
    # give jobs 1 and 2 one packet each, then freeze their state
    for j in (1, 2):
        seeds = np.uint32([100 + j])
        C = np.asarray(field.matmul(expand_rows_jit(seeds, k, S),
                                    field.random_elements(
                                        jax.random.PRNGKey(j), (k, l))))
        srv.offer(j, C[0], seed=int(seeds[0]))
    srv.drain()
    frozen = [(np.asarray(srv.bank.basis(j)).copy(),
               np.asarray(srv.bank.rank)[j]) for j in (1, 2)]
    # now hammer job 0 to completion across several ticks
    P0 = np.asarray(field.random_elements(jax.random.PRNGKey(9),
                                          (k, l)))
    seeds0 = np.arange(1, k + 2, dtype=np.uint32)
    C0 = np.asarray(field.matmul(expand_rows_jit(seeds0, k, S),
                                 jnp.asarray(P0)))
    for g in range(k + 1):
        srv.offer(0, C0[g], seed=int(seeds0[g]))
    srv.drain()
    assert srv.completion(0) is not None
    np.testing.assert_array_equal(srv.result(0), P0)
    for (B_before, r_before), j in zip(frozen, (1, 2), strict=True):
        np.testing.assert_array_equal(
            np.asarray(srv.bank.basis(j)), B_before)
        assert np.asarray(srv.bank.rank)[j] == r_before


def test_mixed_wire_formats_within_one_job():
    """A single job may receive seeded and materialized packets
    interchangeably (registry sibling dispatch at per-packet grain)."""
    k, l = 5, 12
    field = get_field(S)
    P = np.asarray(field.random_elements(jax.random.PRNGKey(3),
                                         (k, l)))
    seeds = np.arange(10, 10 + k, dtype=np.uint32)
    A = np.asarray(expand_rows_jit(seeds, k, S))
    C = np.asarray(field.matmul(jnp.asarray(A), jnp.asarray(P)))
    srv = DecodeServer(slots=1, K=k, L=l, s=S, g_tick=3)
    srv.submit(0, k, l)
    for g in range(k):
        if g % 2:
            srv.offer(0, C[g], seed=int(seeds[g]))        # seeded
        else:
            srv.offer(0, C[g], row=A[g])                   # materialized
    srv.drain()
    c = srv.completion(0)
    assert c is not None and c.arrivals == k
    np.testing.assert_array_equal(srv.result(0), P)


def test_late_packets_dropped_and_slot_reused():
    """Packets after rank K are dropped; the freed slot admits the
    next waiting job (more jobs than slots)."""
    k, l = 3, 4
    field = get_field(S)
    srv = DecodeServer(slots=1, K=k, L=l, s=S, g_tick=2)
    mats = []
    for j in range(3):
        seeds = (np.arange(k + 2) + 50 * (j + 1)).astype(np.uint32)
        P = np.asarray(field.random_elements(jax.random.PRNGKey(20 + j),
                                             (k, l)))
        C = np.asarray(field.matmul(expand_rows_jit(seeds, k, S),
                                    jnp.asarray(P)))
        mats.append((seeds, C, P))
        srv.submit(j, k, l)
    assert srv.max_concurrent == 1
    for j, (seeds, C, _) in enumerate(mats):
        for g in range(k + 2):                 # 2 redundant packets
            srv.offer(j, C[g], seed=int(seeds[g]))
        srv.drain()
    for j, (_, _, P) in enumerate(mats):
        assert srv.completion(j) is not None
        np.testing.assert_array_equal(srv.result(j), P)
    assert srv.max_concurrent == 1             # never two slots live
    assert srv.late_dropped > 0                # redundant tail dropped
    c = srv.completions[0]
    assert srv.offer(0, mats[0][1][0], seed=int(mats[0][0][0])) is False
    assert srv.completions[0] == c             # completion unchanged


# ---------------------------------------------------------------------------
# determinism: same trace => same results, whatever the batching
# ---------------------------------------------------------------------------

def test_serving_recorded_trace_twice_is_identical():
    trace = poisson_multitenant_trace(8, K=6, L=24, extra_packets=4,
                                      duplicate_rate=0.1, seed=21)
    a = serve_trace(trace, slots=4, g_tick=4)
    b = serve_trace(trace, slots=4, g_tick=4)
    assert _sig(a) == _sig(b)
    assert a.packets_ingested == b.packets_ingested
    assert a.ticks == b.ticks and a.dispatches == b.dispatches


def test_completion_invariant_to_tick_batching():
    """g_tick / slots / dispatch mode only change wall clock — decoded
    payloads and completion arrival counts are invariant."""
    trace = poisson_multitenant_trace(
        6, K=[3, 4, 5, 3, 4, 5], L=[8, 10, 6, 8, 10, 6],
        extra_packets=3, duplicate_rate=0.15, seed=5)
    ref = None
    for slots, g_tick, batched in [(2, 1, True), (3, 4, True),
                                   (6, 8, True), (4, 2, False),
                                   (6, 1, False)]:
        rep = serve_trace(trace, slots=slots, g_tick=g_tick,
                          batched=batched)
        assert rep.completed == 6
        sig = _sig(rep)
        assert ref is None or sig == ref, (slots, g_tick, batched)
        ref = sig


def test_trace_json_roundtrip_serves_identically(tmp_path):
    trace = poisson_multitenant_trace(5, K=4, L=16, extra_packets=3,
                                      seed=13)
    path = tmp_path / "trace.json"
    trace.save(path)
    loaded = ServeTrace.load(path)
    assert _sig(serve_trace(trace)) == _sig(serve_trace(loaded))


def test_regression_fixture_trace():
    """The committed fixture decodes to its recorded completion
    signature — any drift in seeds, scheduler, bank, or field
    arithmetic shows up here."""
    trace = ServeTrace.load(DATA / "serve_trace.json")
    expected = trace.extra["expected"]
    for g_tick in (1, 4, 8):
        rep = serve_trace(trace, slots=4, g_tick=g_tick)
        assert rep.completed == len(expected)
        for c in rep.completions:
            e = expected[str(c.job)]
            assert c.arrivals == e["arrivals"], f"job {c.job}"
            assert c.payload_sha == e["payload_sha"], f"job {c.job}"


# ---------------------------------------------------------------------------
# the example (fast-tier smoke, same pattern as seeded_overhead)
# ---------------------------------------------------------------------------

def test_serve_example_runs():
    mod = runpy.run_path(str(ROOT / "examples" / "serve_decode.py"))
    stats = mod["main"]()
    assert stats["completed"] == stats["jobs"]
    assert stats["dispatches_batched"] < stats["dispatches_sequential"]


def test_fixture_matches_generator():
    """The fixture is the documented generator call, frozen — keep the
    provenance honest so it can be regenerated knowingly."""
    gen = trace_from_fixture_params()
    fix = ServeTrace.load(DATA / "serve_trace.json")
    assert [j for j in gen.jobs] == [j for j in fix.jobs]
    np.testing.assert_array_equal(gen.row_seeds, fix.row_seeds)
    np.testing.assert_array_equal(gen.payloads, fix.payloads)


def trace_from_fixture_params() -> ServeTrace:
    """Exact generator call behind tests/data/serve_trace.json."""
    return poisson_multitenant_trace(
        6, K=[3, 5, 4, 6, 3, 5], L=[8, 16, 12, 20, 8, 16],
        extra_packets=3, seeded="mixed", duplicate_rate=0.2, seed=42)
