"""FedNC quickstart: one federated round with network coding.

Five clients locally train the paper's CNN, RLNC-encode their parameter
packets over GF(2^8), ship them through a lossy channel, and the server
Gaussian-eliminates back the originals — bit-exactly — then aggregates.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fednc
from repro.core.channel import ErasureChannel
from repro.core.fednc import FedNCConfig
from repro.data import iid_partition, make_image_dataset
from repro.data.synthetic import batches
from repro.federation import LocalTrainer
from repro.models.cnn import cnn_loss, init_cnn, merge_bn_stats
from repro.optim import adam


def main() -> None:
    K = 5
    ds = make_image_dataset(400, seed=0, size=16)
    parts = iid_partition(ds.labels, K, seed=1)
    trainer = LocalTrainer(
        loss_fn=lambda p, b: cnn_loss(p, b, train=True),
        optimizer=adam(1e-3), local_epochs=1,
        state_merge=merge_bn_stats)

    global_params = init_cnn(jax.random.PRNGKey(0), image_size=16)

    # --- local training (paper: local_train(w, D_k)) -------------------
    client_params = []
    for k in range(K):
        it = batches(ds.subset(parts[k]), 32, seed=k, epochs=1)
        p_k, loss_k = trainer.train(global_params, it)
        client_params.append(p_k)
        print(f"client {k}: local loss {loss_k:.4f}")

    # --- FedNC round: encode -> channel -> decode -> aggregate ---------
    cfg = FedNCConfig(s=8, extra_tuples=2)   # 2 spare coded packets
    chan = ErasureChannel(p_erase=0.2, seed=3)
    res = fednc.fednc_round(client_params, [1 / K] * K, global_params,
                            cfg, jax.random.PRNGKey(7), channel=chan)
    print(f"\nFedNC: sent {K + cfg.extra_tuples} coded tuples, "
          f"{res.report.delivered} survived erasure, "
          f"decoded={res.decoded}")

    # --- the headline property: identical to lossless FedAvg -----------
    ref = fednc.fedavg_round(client_params, [1 / K] * K, global_params)
    if res.decoded:
        diffs = [
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(res.global_params),
                            jax.tree_util.tree_leaves(ref.global_params),
                            strict=True)]
        print(f"max |FedNC - FedAvg| over all parameters: {max(diffs)} "
              "(bit-exact coding)")
    else:
        print("round skipped (Alg. 1 else-branch); w_t = w_{t-1}")


if __name__ == "__main__":
    main()
