"""End-to-end driver: federated training of a language model with
FedNC-coded update aggregation.

Default runs the xlstm-125m family at reduced size for CPU; pass
--full to train the actual 125M-class config (slow on CPU, sized for a
TPU host).  A few hundred steps show the planted-bigram loss dropping.

    PYTHONPATH=src python examples/train_fl_lm.py --steps 200
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--agg", default="fednc_blocked")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--steps", str(args.steps),
           "--agg", args.agg, "--batch", "8", "--seq", "128",
           "--clients", "4", "--log-every", "10"]
    if not args.full:
        cmd.append("--reduced")
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
