"""Full paper-experiment driver (Fig. 3, Fig. 4, Table I).

Synthetic CIFAR-10 stand-in (offline container; DESIGN.md §3), the
paper's 6-conv CNN, N clients / K participants with blind-box
reception, iid and mixed non-iid splits, FedAvg vs FedNC across
(s, η) settings.

    PYTHONPATH=src python examples/paper_experiments.py \
        --rounds 30 --clients 100 --participants 10 --out results.json
"""
import argparse
import json

import jax
import numpy as np

from repro.core.channel import BlindBoxChannel, MultiHopChannel
from repro.core.fednc import FedNCConfig
from repro.core.security import error_probability_bound
from repro.data import (iid_partition, make_image_dataset,
                        mixed_noniid_partition)
from repro.federation import (FedAvgStrategy, FedNCStrategy, FLExperiment,
                              LocalTrainer, run_experiment)
from repro.federation.rounds import final_accuracy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn, merge_bn_stats
from repro.optim import adam


def build(split, strategy, N, K, n_samples, seed, epochs, size):
    ds = make_image_dataset(n_samples, seed=0, size=size, noise=1.0)
    test = make_image_dataset(max(n_samples // 5, 200), seed=99,
                              size=size, noise=1.0)
    parts = (iid_partition(ds.labels, N, seed=1) if split == "iid"
             else mixed_noniid_partition(ds.labels, N, seed=1))
    trainer = LocalTrainer(
        loss_fn=lambda p, b: cnn_loss(p, b, train=True),
        optimizer=adam(2e-3), local_epochs=epochs,
        state_merge=merge_bn_stats)
    return FLExperiment(
        trainer=trainer, strategy=strategy, partitions=parts,
        dataset=ds, test_set=test,
        eval_fn=lambda p, x, y: cnn_accuracy(p, x, y),
        clients_per_round=K, batch_size=16, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--participants", type=int, default=10)
    ap.add_argument("--samples", type=int, default=4000)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--out", default="EXPERIMENTS/paper_experiments.json")
    ap.add_argument("--skip-scale", action="store_true")
    args = ap.parse_args()

    N, K = args.clients, args.participants
    results = {}

    # ---- Table I: error probability + accuracy per (s, η) -------------
    settings = [("fedavg", None, None), ("fednc", 1, 1), ("fednc", 4, 1),
                ("fednc", 8, 1), ("fednc", 8, 100)]
    for split in ("iid", "noniid"):
        for scheme, s, eta in settings:
            tag = (f"{split}/{scheme}" if s is None
                   else f"{split}/{scheme}_s{s}_eta{eta}")
            if scheme == "fedavg":
                strat = FedAvgStrategy(channel=BlindBoxChannel(budget=K))
            else:
                # η > 1 modeled by replacing the blind box with η
                # recoding hops (decode-failure statistics of Prop. 2)
                chan = (BlindBoxChannel(budget=K) if eta == 1
                        else MultiHopChannel(eta=eta))
                strat = FedNCStrategy(config=FedNCConfig(s=s),
                                      channel=chan)
            exp = build(split, strat, N, K, args.samples, 0,
                        args.local_epochs, args.image_size)
            params = init_cnn(jax.random.PRNGKey(0),
                              image_size=args.image_size)
            logs = run_experiment(exp, params, rounds=args.rounds,
                                  eval_every=max(args.rounds // 5, 1),
                                  verbose=False)
            acc = final_accuracy(logs)
            fail = 1.0 - np.mean([l.decoded for l in logs])
            bound = (error_probability_bound(s, eta)
                     if s is not None else None)
            results[tag] = {"acc": acc, "decode_fail_rate": fail,
                            "pe_bound": bound}
            print(f"{tag:28s} acc={acc:.4f} fail={fail:.3f} "
                  f"bound={bound}", flush=True)

    # ---- Fig. 4: scale sweep (N, participation) ------------------------
    if not args.skip_scale:
        for N2 in (N, 2 * N):
            for scheme in ("fedavg", "fednc"):
                strat = (FedNCStrategy(config=FedNCConfig(s=8),
                                       channel=BlindBoxChannel(budget=K))
                         if scheme == "fednc"
                         else FedAvgStrategy(
                             channel=BlindBoxChannel(budget=K)))
                exp = build("noniid", strat, N2, K, args.samples, 0,
                            args.local_epochs, args.image_size)
                params = init_cnn(jax.random.PRNGKey(0),
                                  image_size=args.image_size)
                logs = run_experiment(
                    exp, params, rounds=args.rounds,
                    eval_every=max(args.rounds // 5, 1))
                acc = final_accuracy(logs)
                results[f"scale/N{N2}_{scheme}"] = {"acc": acc}
                print(f"scale N={N2} {scheme}: acc={acc:.4f}", flush=True)

    import os
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
