"""Serving example: batched prefill + greedy decode on any arch config.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-8b
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", args.arch, "--batch", str(args.batch),
           "--new-tokens", str(args.new_tokens)]
    if not args.full:
        cmd.append("--reduced")
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
