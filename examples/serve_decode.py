"""Multi-tenant decode serving: batched vs per-job dispatch.

Many federated rounds land on one decode server at once; each round is
a *job* with its own reduced-basis state in a `DecoderBank` slot, and
every scheduler tick drains all queues into ONE lane-packed ingest
dispatch (continuous batching).  This example generates a Poisson
multi-tenant trace (mixed seeded + materialized wire formats), serves
it twice — batched and per-job sequential — and shows the two modes
produce byte-identical decodes while the batched server does a
fraction of the dispatches.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.serve import poisson_multitenant_trace, serve_trace

JOBS = 10        # concurrent federated rounds
K = 12           # generation size per round
L = 256          # payload symbols per packet
SLOTS = 8        # decoder-bank slots (rounds in flight)
EXTRA = 5        # redundant tuples per round beyond K


def main() -> dict:
    trace = poisson_multitenant_trace(
        JOBS, K, L, rate=4.0, extra_packets=EXTRA, seeded="mixed",
        duplicate_rate=0.1, seed=7)

    batched = serve_trace(trace, slots=SLOTS, g_tick=8, batched=True)
    seq = serve_trace(trace, slots=SLOTS, g_tick=8, batched=False)

    def sig(r):
        return [(c.job, c.arrivals, c.payload_sha)
                for c in r.completions]

    assert sig(batched) == sig(seq), "batched decode drifted"
    assert batched.completed == JOBS

    p50, p99 = batched.latency_percentiles()
    stats = {
        "jobs": JOBS, "K": K, "L": L, "slots": SLOTS,
        "packets": batched.packets_ingested,
        "ticks": batched.ticks,
        "dispatches_batched": batched.dispatches,
        "dispatches_sequential": seq.dispatches,
        "max_concurrent": batched.max_concurrent,
        "completed": batched.completed,
        "p50_latency_s": p50, "p99_latency_s": p99,
    }

    print(f"{JOBS} rounds x (K={K}+{EXTRA}) tuples, L={L}, "
          f"{SLOTS} slots, mixed seeded/materialized wire")
    print(f"  batched:    {batched.ticks} ticks -> "
          f"{batched.dispatches} dispatches, all {batched.completed} "
          "jobs decoded")
    print(f"  sequential: {seq.ticks} ticks -> "
          f"{seq.dispatches} dispatches, byte-identical payloads")
    print(f"  p50 job latency {p50 * 1e3:.0f} ms, p99 {p99 * 1e3:.0f} ms "
          "(includes one-off jit compile)")
    return stats


if __name__ == "__main__":
    main()
