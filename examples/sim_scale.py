"""Population-scale network simulation: Prop. 1 on a simulated clock.

Sweeps population sizes under a chosen straggler profile and prints,
per population, how long the server waits to decode — FedNC stops at
the first rank-K prefix of arrivals (StreamDecoder), FedAvg waits for
every cohort member (blind-box collector) — plus the measured draw
ratio against the K·H(K)/K prediction from `core.coupon`.

    PYTHONPATH=src python examples/sim_scale.py
    PYTHONPATH=src python examples/sim_scale.py \
        --populations 1000 1000000 --straggler pareto --rounds 200
    PYTHONPATH=src python examples/sim_scale.py --dropout 0.1
"""
from __future__ import annotations

import argparse

from repro import obs
from repro.core import coupon
from repro.sim import (STRAGGLER_PROFILES, NetworkSimulator,
                       PopulationConfig, SimConfig)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--populations", type=int, nargs="+",
                    default=[10**3, 10**4, 10**5, 10**6])
    ap.add_argument("--clients-per-round", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--straggler", default="lognormal",
                    choices=sorted(STRAGGLER_PROFILES))
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    K = args.clients_per_round
    predicted = (coupon.expected_draws_fedavg(K)
                 / coupon.expected_draws_fednc(K, 8))
    print(f"cohort K={K}, straggler={args.straggler}, "
          f"rounds={args.rounds}, p_dropout={args.dropout}")
    print(f"Prop. 1 predicted draw ratio K·H(K)/~K = {predicted:.3f}\n")
    hdr = (f"{'population':>10} {'t_rankK':>9} {'t_allK':>9} "
           f"{'speedup':>8} {'draw_ratio':>10} {'rel_err':>8} "
           f"{'wall_s':>7}")
    print(hdr)
    print("-" * len(hdr))
    for pop in args.populations:
        cfg = SimConfig(
            population=PopulationConfig(n_clients=pop,
                                        p_dropout=args.dropout),
            clients_per_round=K,
            gap=STRAGGLER_PROFILES[args.straggler],
            timeout=1e4 if args.dropout else float("inf"),
            seed=args.seed)
        with obs.timed("sim.scale", cat="sim", pop=pop) as sw:
            trace = NetworkSimulator(cfg).run(args.rounds)
        wall = sw.dur_s
        s = trace.summary()
        if "draw_ratio" not in s:    # dropout blocked every FedAvg round
            print(f"{pop:>10,} fednc_decode_rate="
                  f"{s['fednc_decode_rate']:.2f} fedavg_complete_rate="
                  f"{s['fedavg_complete_rate']:.2f} "
                  f"(FedAvg starved by dropout)  wall={wall:.2f}s")
            continue
        rel = abs(s["draw_ratio"] - predicted) / predicted
        print(f"{pop:>10,} {s['time_to_rank_k_mean']:>9.3f} "
              f"{s['time_to_all_k_mean']:>9.3f} "
              f"{s['time_speedup']:>8.2f} {s['draw_ratio']:>10.3f} "
              f"{rel:>7.2%} {wall:>7.2f}")


if __name__ == "__main__":
    main()
