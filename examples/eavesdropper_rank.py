"""What an eavesdropper actually learns: rank vs fraction of links tapped.

The paper's security argument (§III-A.2) is the rank-K wall: RLNC
combinations reveal nothing until the attacker's basis spans all K
source packets.  This example makes the wall visible twice over one
hierarchical round (`repro.engine.multi_edge_coding_matrix`):

* **edge taps** — capturing every row of e < E edge links yields
  coding vectors supported on < K columns: rank is structurally
  capped below K, however many packets are captured.
* **per-tuple interception** — a flat attacker capturing each of the
  n transmitted tuples with probability p climbs toward K only as its
  intercept count passes K, matching the closed form
  `core.security.eavesdropper_leak_probability`.

    PYTHONPATH=src python examples/eavesdropper_rank.py
"""
import jax
import numpy as np

from repro.adversary import EavesdropperView, tap_edges
from repro.core.security import eavesdropper_leak_probability
from repro.engine import CodingEngine, EngineConfig

EDGES = 4        # edge servers in the hierarchy
PER_EDGE = 4     # clients per edge  (K = EDGES * PER_EDGE)
SPARE = 1        # redundant rows per edge
S = 8
TRIALS = 40      # Monte-Carlo trials for the interception sweep
SEED = 7


def main() -> dict:
    K = EDGES * PER_EDGE
    edges = [tuple(range(e * PER_EDGE, (e + 1) * PER_EDGE))
             for e in range(EDGES)]
    n_out = [len(ids) + SPARE for ids in edges]
    engine = CodingEngine(EngineConfig(s=S, kernel="jnp_packed"))

    print(f"hierarchy: {EDGES} edges x {PER_EDGE} clients "
          f"(K = {K}), +{SPARE} spare row per edge\n")
    print("edge taps (structural wall — rank capped by tapped columns):")
    edge_rows = []
    for tapped in range(EDGES + 1):
        ranks = []
        for t in range(TRIALS):
            A = engine.multi_edge_coding_matrix(
                jax.random.PRNGKey(SEED + t), edges, K, n_out)
            view = EavesdropperView(K=K, s=S, seed=t)
            view.observe(tap_edges(A, edges, range(tapped),
                                   spare_per_edge=SPARE))
            ranks.append(view.rank)
        leak = float(np.mean([r == K for r in ranks]))
        edge_rows.append({"tapped": tapped,
                          "rank_mean": float(np.mean(ranks)),
                          "full_leak_rate": leak})
        bar = "#" * int(round(np.mean(ranks)))
        print(f"  {tapped}/{EDGES} edges: rank {np.mean(ranks):5.2f}"
              f"/{K}  leak {leak:4.2f}  |{bar}")
        assert tapped == EDGES or leak == 0.0, "rank wall breached!"

    # flat sweep: uniform coding rows, so the closed form applies
    # exactly (the hierarchy's block rows are *harder* to leak from)
    n = sum(n_out)
    print("\nper-tuple interception (probabilistic wall vs closed form):")
    leak_rows = []
    for p in (0.3, 0.5, 0.7, 0.9):
        leaks = ranks = 0
        for t in range(TRIALS):
            A = engine.coding_matrix(jax.random.PRNGKey(SEED + t), n, K)
            view = EavesdropperView(K=K, s=S, seed=1000 + t,
                                    p_intercept=p)
            view.intercept(np.asarray(A))
            leaks += int(view.full_leak)
            ranks += view.rank
        closed = eavesdropper_leak_probability(n, K, p, s=S)
        leak_rows.append({"p": p, "measured": leaks / TRIALS,
                          "closed_form": closed,
                          "rank_mean": ranks / TRIALS})
        print(f"  p={p:.1f}: rank {ranks / TRIALS:5.2f}/{K}  "
              f"leak {leaks / TRIALS:4.2f}  "
              f"(closed form {closed:.3f})")
    print("\n< K independent combinations decode nothing; the attacker"
          "\nneeds every edge (or > K tuples) before anything leaks.")
    return {"edge_taps": edge_rows, "interception": leak_rows}


if __name__ == "__main__":
    main()
