"""Wire overhead of one coded round: materialized rows vs 4-byte seeds.

The classical RLNC objection at large generation size K is the header:
every packet carries its K-symbol coding row, so a round of n tuples
ships n·(K + L) symbols.  The seeded kernel family
(`repro.engine.registry`, `repro.core.seeds`) replaces the row with
the uint32 seed that generated it — n·(4 + L) bytes — and regenerates
coefficients inside the GF matmul.  This example runs BOTH pipelines
at the paper-scale K = 128, proves them byte-identical, and prints the
per-round wire accounting.

    PYTHONPATH=src python examples/seeded_overhead.py
"""
import jax
import jax.numpy as jnp

from repro.core.packets import packet_wire_bytes
from repro.engine import CodingEngine, EngineConfig

K = 128          # generation size (clients per round)
L = 4096         # payload symbols per packet
S = 8
EXTRA = 4        # erasure-headroom tuples beyond K


def main() -> dict:
    n = K + EXTRA
    key = jax.random.PRNGKey(0)
    P = jax.random.randint(jax.random.fold_in(key, 1), (K, L),
                           0, 1 << S, dtype=jnp.uint8)

    seeded = CodingEngine(EngineConfig(s=S, kernel="jnp_packed_seeded"))
    mat = CodingEngine(EngineConfig(s=S, kernel="jnp_packed"))

    # the same round, both wire formats: the seeded engine draws
    # 4-byte row seeds, the materialized oracle encodes their expansion
    seeds = seeded.coding_seeds(jax.random.fold_in(key, 2), n)
    sb = seeded.encode_seeded(P, seeds)
    mb = mat.encode(P, seeded.expand_seeds(seeds, K))
    assert (sb.C == mb.C).all(), "seeded encode drifted from the oracle"

    ok_s, P_s = seeded.decode(sb)
    ok_m, P_m = mat.decode(mb)
    assert ok_s and ok_m and (P_s == P_m).all() and (P_s == P).all()

    per_mat = packet_wire_bytes(K, L, S, seeded=False)
    per_sed = packet_wire_bytes(K, L, S, seeded=True)
    stats = {
        "K": K, "L": L, "s": S, "tuples": n,
        "bytes_per_packet_materialized": per_mat,
        "bytes_per_packet_seeded": per_sed,
        "bytes_per_round_materialized": per_mat * n,
        "bytes_per_round_seeded": per_sed * n,
        "header_shrink": K * S // 8 - 4,
        "round_ratio": per_sed / per_mat,
    }

    print(f"one round, n = K + {EXTRA} = {n} tuples, "
          f"K = {K}, L = {L}, s = {S}")
    print(f"  materialized: {per_mat:,} B/packet "
          f"-> {stats['bytes_per_round_materialized']:,} B/round")
    print(f"  seeded:       {per_sed:,} B/packet "
          f"-> {stats['bytes_per_round_seeded']:,} B/round")
    print(f"  header: {K * S // 8} B -> 4 B per packet "
          f"({stats['round_ratio']:.4f}x round bytes, "
          "decode byte-identical)")
    return stats


if __name__ == "__main__":
    main()
