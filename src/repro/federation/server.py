"""Server aggregation strategies: FedAvg (paper §II-A baseline) and
FedNC (paper Alg. 1), both behind one interface so round loops and
experiments swap them freely.

The channel between clients and server is pluggable (core.channel):
`None` (ideal), ErasureChannel, BlindBoxChannel, MultiHopChannel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fednc as fednc_mod
from repro.core.channel import (ArrivalSchedule, AsyncChannelReport,
                                BlindBoxChannel, ChannelReport)
from repro.core.fednc import FedNCConfig, RoundResult
from repro.core.rlnc import random_coding_matrix


@dataclass
class FedAvgStrategy:
    """Classic FedAvg; under a BlindBoxChannel the server aggregates
    whatever K draws it happens to receive (duplicates included) —
    the paper's 'blind box effect'."""

    channel: Any = None

    def aggregate(self, client_params: Sequence[Any],
                  weights: Sequence[float], prev_global: Any,
                  rng: np.random.Generator) -> RoundResult:
        if isinstance(self.channel, BlindBoxChannel):
            K = len(client_params)
            draws = rng.integers(0, K, size=self.channel.budget)
            chosen = [client_params[i] for i in draws]
            w = np.asarray([weights[i] for i in draws], np.float32)
            w = w / w.sum()
            agg = jax.tree_util.tree_map(
                lambda *xs: sum(
                    wk * jnp.asarray(x, jnp.float32)
                    for wk, x in zip(w, xs,
                                     strict=True)).astype(xs[0].dtype),
                *chosen)
            distinct = len(set(draws.tolist()))
            from repro.core.channel import ChannelReport
            rep = ChannelReport(self.channel.budget, self.channel.budget,
                                True, distinct_sources=distinct)
            return RoundResult(agg, True, rep, distinct)
        return fednc_mod.fedavg_round(client_params, weights, prev_global,
                                      channel=self.channel)


@dataclass
class FedNCStrategy:
    """FedNC (Alg. 1).  Under a BlindBoxChannel every received packet
    is a *fresh coded* packet — random mixtures of ALL K participants —
    so any full-rank K of them aggregate every client's contribution."""

    config: FedNCConfig = field(default_factory=FedNCConfig)
    channel: Any = None

    def aggregate(self, client_params: Sequence[Any],
                  weights: Sequence[float], prev_global: Any,
                  rng: np.random.Generator) -> RoundResult:
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        cfg = self.config
        if isinstance(self.channel, BlindBoxChannel):
            # encode once per emitted packet: the network multicasts
            # fresh combinations; server keeps `budget` of them.
            engine = fednc_mod.engine_for(cfg)
            P, spec = engine.packetize(client_params)
            K = P.shape[0]
            n = self.channel.budget
            A = random_coding_matrix(key, n, K, cfg.s)
            batch = engine.encode(P, A)
            # decode_and_aggregate row-selects on-device when n > K and
            # reports rank failure itself — no host-side rank check.
            res = fednc_mod.decode_and_aggregate(
                batch, spec, weights, prev_global, cfg)
            res.report = ChannelReport(n, n, res.decoded)
            return res
        return fednc_mod.fednc_round(client_params, weights, prev_global,
                                     cfg, key, channel=self.channel)


@dataclass
class AsyncFedNCStrategy:
    """FedNC with an asynchronous server: Prop. 1 made operational.

    The network multicasts `budget` coded tuples whose arrival times
    come from `schedule_fn`; the server feeds them, *in arrival
    order*, to a :class:`repro.engine.stream.StreamDecoder` and stops
    listening the instant rank K is reached — it aggregates from the
    first rank-K prefix of arrivals (~K packets) instead of waiting
    for the whole batch.  The report records how many arrivals were
    consumed and the simulated clock at decode, so round loops can
    plot time-to-decode instead of just decode/no-decode.

    When the driver passes per-client ``compute_times`` (see
    `repro.sim.ComputeModel` and ``run_async_experiment``), each
    multicast tuple is attributed a uniformly random source client —
    the blind box again — and delayed by that client's local-training
    time: packets from fast clients arrive while slow clients still
    compute.  The report then carries both clocks (``sim_time``
    coupled, ``sim_time_network`` network-only, from the same gap
    draws), so the compute contribution to time-to-decode is a
    measurement, not a model assumption.
    """

    config: FedNCConfig = field(default_factory=FedNCConfig)
    budget: int = 0     # coded tuples multicast per round; 0 -> K + 8
    # (n, rng) -> ArrivalSchedule for the n multicast tuples; None
    # means transmission order with unit gaps (an ideal pipe)
    schedule_fn: Optional[
        Callable[[int, np.random.Generator], ArrivalSchedule]] = None

    def aggregate(self, client_params: Sequence[Any],
                  weights: Sequence[float], prev_global: Any,
                  rng: np.random.Generator, *,
                  compute_times=None) -> RoundResult:
        from repro.engine.stream import StreamDecoder, stream_decode
        cfg = self.config
        engine = fednc_mod.engine_for(cfg)
        # the config-honoring helpers: quantize_bits via packetize,
        # systematic/coding_density via the engine's matrix draw
        P, spec, qspecs = fednc_mod.packetize_clients(client_params, cfg)
        K = P.shape[0]
        n = self.budget if self.budget else K + 8
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        batch = engine.encode(P, engine.coding_matrix(key, n, K))
        if self.schedule_fn is not None:
            sched_net = self.schedule_fn(n, rng)
            if sched_net.n != n:
                raise ValueError(
                    f"schedule covers {sched_net.n} arrivals, need {n}")
        else:
            sched_net = ArrivalSchedule(np.arange(1, n + 1, dtype=float))
        if compute_times is not None:
            ct = np.asarray(compute_times, np.float64)
            if ct.shape[0] != K:
                raise ValueError(
                    f"compute_times covers {ct.shape[0]} clients, "
                    f"need {K}")
            # blind-box source attribution: each multicast tuple waits
            # for a uniformly random client's local training
            sources = rng.integers(0, K, size=n)
            sched = sched_net.offset_by(ct[sources])
        else:
            sched = sched_net
        ok, P_hat, consumed = stream_decode(batch, cfg.s,
                                            order=sched.order)
        sim_time = sched.time_of(consumed) if consumed else 0.0
        if compute_times is None:
            sim_time_network = sim_time
        else:
            # the counterfactual clock: same gap draws, no compute.
            # Rank-only replay (L=0) — one tiny scan over the coding
            # vectors, no payload traffic.
            rank_dec = StreamDecoder(K=K, L=0, s=cfg.s)
            rank_dec.ingest(batch.A[jnp.asarray(sched_net.order,
                                                jnp.int32)])
            g_net = rank_dec.decoded_at or consumed
            sim_time_network = (sched_net.time_of(g_net)
                                if g_net else 0.0)
        report = AsyncChannelReport(
            sent=n, delivered=consumed, decodable=bool(ok),
            consumed=consumed, sim_time=sim_time,
            sim_time_network=sim_time_network)
        if not ok:
            return RoundResult(prev_global, False, report, 0)
        agg = fednc_mod.aggregate_decoded(P_hat, spec, weights, cfg,
                                          qspecs=qspecs)
        return RoundResult(agg, True, report, K)


@dataclass
class HierarchicalFedNCStrategy:
    """Hierarchical FedNC (paper §III): clients upload to trusted edge
    servers, each edge emits K_e + `spare_per_edge` random combinations
    in the global coding-vector space, and the central server decodes
    the WAN-delivered stack.

    Thin adapter over the engine's fused
    :meth:`~repro.engine.CodingEngine.multi_edge_round` — the whole
    edge tier is one chunk-streamed dispatch, not E re-entries."""

    config: FedNCConfig = field(default_factory=FedNCConfig)
    num_edges: int = 2
    spare_per_edge: int = 0
    channel: Any = None           # the WAN hop (edge -> central server)

    def aggregate(self, client_params: Sequence[Any],
                  weights: Sequence[float], prev_global: Any,
                  rng: np.random.Generator) -> RoundResult:
        from repro.core.hierarchy import hierarchical_fednc_round
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        return hierarchical_fednc_round(
            client_params, weights, prev_global, self.config, key,
            num_edges=self.num_edges, spare_per_edge=self.spare_per_edge,
            wan_channel=self.channel)
