"""Async round orchestration: the outer FL loop on a simulated clock.

`rounds.run_experiment` is lockstep — every round costs "1" and the
wall clock of waiting does not exist.  This driver runs the same
local-training loop but aggregates through an
:class:`~repro.federation.server.AsyncFedNCStrategy`, so each round
yields the two temporal quantities Prop. 1 is actually about:

* ``consumed``  — arrivals the server listened to before rank K
                  (~K, vs the blind-box collector's K·H(K)), and
* ``sim_time``  — the simulated clock at decode, driven by the
                  arrival schedule (straggler tails included).

`blind_box_schedule` adapts a `repro.sim` gap distribution into the
strategy's ``schedule_fn``, which is how the network simulator's
scenario axis (straggler profile, bandwidth scale) plugs into real
FL training runs.

Passing a :class:`repro.sim.ComputeModel` as ``compute`` closes the
remaining temporal gap: each cohort member gets a simulated local-
training time (modeled FLOP draw, or its *measured* training wall
seconds rescaled), and every packet it sources is delayed by it — the
arrival clock then covers compute + network end to end, and each
round's log carries both ``sim_time`` (coupled) and
``sim_time_network`` (the network-only schedule) so the compute
contribution is directly measurable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro import obs
from repro.core.channel import ArrivalSchedule

from .rounds import FLExperiment, train_cohort


@dataclass
class AsyncRoundLog:
    round: int
    decoded: bool
    n_aggregated: int
    consumed: int         # arrivals until rank K
    sim_time: float       # simulated clock at decode (compute-coupled
                          # when a ComputeModel is configured)
    train_loss: float
    test_acc: float
    wall_s: float
    sim_time_network: float = float("nan")   # network-only decode time


def blind_box_schedule(gap=None, rate_scale: float = 1.0
                       ) -> Callable[[int, np.random.Generator],
                                     ArrivalSchedule]:
    """Arrival schedule factory: i.i.d. gaps from a `repro.sim`
    DistSpec (default unit exponential — the memoryless multicast of
    paper §IV-A), cumulated into arrival times.  Compute coupling
    happens downstream: `AsyncFedNCStrategy` attributes each packet a
    random source client and shifts this schedule with
    :meth:`~repro.core.channel.ArrivalSchedule.offset_by`."""
    def make(n: int, rng: np.random.Generator) -> ArrivalSchedule:
        from repro.sim.distributions import DistSpec
        spec = gap if gap is not None else DistSpec()
        return ArrivalSchedule(np.cumsum(spec.sample(rng, n))
                               / max(rate_scale, 1e-12))
    return make


def run_async_experiment(exp: FLExperiment, init_params: Any,
                         rounds: int, *, eval_every: int = 1,
                         compute: Optional[Any] = None,
                         verbose: bool = False) -> list[AsyncRoundLog]:
    """`rounds.run_experiment`, but the strategy's report must carry
    the async fields (consumed / sim_time) — i.e. AsyncFedNCStrategy
    or anything quacking like it.  Cohort sampling and local training
    are the shared `rounds.train_cohort`, so async and lockstep runs
    stay comparable.

    `compute` (a :class:`repro.sim.ComputeModel`) adds each client's
    simulated local-training time into its packets' arrival clock —
    the round is then genuinely asynchronous end to end: fast clients'
    packets are heard while slow clients are still computing."""
    rng = np.random.default_rng(exp.seed)
    global_params = init_params
    logs: list[AsyncRoundLog] = []

    tr = obs.get_tracer()
    for t in range(rounds):
        with obs.timed("async.round", cat="fl", round=t) as sw:
            client_params, weights, loss, walls = train_cohort(
                exp, rng, global_params)
            if compute is not None:
                ct = compute.times(rng, len(client_params),
                                   measured_wall=walls)
                result = exp.strategy.aggregate(client_params, weights,
                                                global_params, rng,
                                                compute_times=ct)
            else:
                result = exp.strategy.aggregate(client_params, weights,
                                                global_params, rng)
            global_params = result.global_params
            rep = result.report
            consumed = getattr(rep, "consumed", -1)
            sim_time = getattr(rep, "sim_time", float("nan"))
            sim_time_network = getattr(rep, "sim_time_network",
                                       float("nan"))
            if tr.enabled:
                tr.instant("async.decode", cat="fl", round=t,
                           consumed=int(consumed),
                           sim_time=float(sim_time))

            acc = float("nan")
            if (t + 1) % eval_every == 0:
                acc = exp.eval_fn(global_params, exp.test_set.images,
                                  exp.test_set.labels)
            sw.fence((global_params, acc))
        logs.append(AsyncRoundLog(t, bool(result.decoded),
                                  result.n_aggregated, int(consumed),
                                  float(sim_time), loss, acc,
                                  sw.dur_s,
                                  float(sim_time_network)))
        if verbose:
            print(f"round {t:3d} decoded={result.decoded} "
                  f"consumed={consumed} sim_t={sim_time:.3f} "
                  f"net_t={sim_time_network:.3f} acc={acc:.4f}")
    return logs
