"""Client-side local training (paper: local_train(w, D_k), 5 epochs).

Model-agnostic: the trainer owns a jitted SGD/Adam step over a
user-supplied `loss_fn(params, batch) -> (loss, aux)` and runs E local
epochs over the client's partition.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, apply_updates


@dataclass
class LocalTrainer:
    """loss_fn(params, batch) -> (loss, aux).  If `state_merge` is set,
    it is called as state_merge(params, aux) after every optimizer step
    — this is how non-gradient state (e.g. the CNN's BatchNorm running
    statistics) flows back into the client parameters so that FedNC
    packets carry it."""

    loss_fn: Callable[[Any, Any], tuple[jnp.ndarray, Any]]
    optimizer: Optimizer
    local_epochs: int = 5
    state_merge: Callable[[Any, Any], Any] = None

    def __post_init__(self):
        def step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            updates, opt_state = self.optimizer.update(
                grads, opt_state, params)
            params = apply_updates(params, updates)
            if self.state_merge is not None:
                params = self.state_merge(params, aux)
            return params, opt_state, loss
        self._step = jax.jit(step)

    def train(self, params: Any, batch_iter: Iterable) -> tuple[Any, float]:
        """Run local epochs; returns (new_params, mean_loss).

        `batch_iter` must already encode the epoch count (see
        data.synthetic.batches(epochs=...)); fresh optimizer state per
        round, as in FedAvg."""
        opt_state = self.optimizer.init(params)
        losses = []
        for batch in batch_iter:
            params, opt_state, loss = self._step(params, opt_state, batch)
            losses.append(float(loss))
        mean = sum(losses) / max(len(losses), 1)
        return params, mean
