"""Round orchestration: the outer FL loop of Algorithm 1.

Each round: sample K participants -> local training -> strategy
aggregation (FedAvg or FedNC, through the configured channel) ->
evaluate the global model.  Histories feed the paper-experiment
benchmarks (Fig. 3 / Fig. 4 / Table I).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import numpy as np

from repro import obs
from repro.data.synthetic import SyntheticImageDataset, batches

from .client import LocalTrainer


@dataclass
class RoundLog:
    round: int
    decoded: bool
    n_aggregated: int
    train_loss: float
    test_acc: float
    wall_s: float


@dataclass
class FLExperiment:
    """Everything one FL run needs, bundled."""
    trainer: LocalTrainer
    strategy: Any                       # FedAvgStrategy | FedNCStrategy
    partitions: Sequence[np.ndarray]    # per-client index sets
    dataset: SyntheticImageDataset
    test_set: SyntheticImageDataset
    eval_fn: Callable[[Any, Any, Any], float]   # (params, x, y) -> acc
    clients_per_round: int = 10
    batch_size: int = 32
    seed: int = 0


def train_cohort(exp: FLExperiment, rng: np.random.Generator,
                 global_params: Any
                 ) -> tuple[list, np.ndarray, float, np.ndarray]:
    """Sample this round's participants and run local training.

    Shared by the lockstep and async round drivers (identical RNG
    consumption, so their client sampling stays comparable).  Returns
    (client_params, normalized size weights, mean local loss,
    per-client training wall seconds) — the wall times feed the
    *measured* mode of :class:`repro.sim.ComputeModel`, which couples
    local compute into the async arrival schedule."""
    N = len(exp.partitions)
    part = rng.choice(N, size=exp.clients_per_round, replace=False)
    client_params, losses, sizes, walls = [], [], [], []
    for k in part:
        idx = exp.partitions[k]
        ds_k = exp.dataset.subset(idx)
        it = batches(ds_k, min(exp.batch_size, max(len(ds_k), 1)),
                     seed=int(rng.integers(0, 2**31 - 1)),
                     epochs=exp.trainer.local_epochs)
        with obs.timed("fl.local_train", cat="fl",
                       client=int(k)) as sw:
            p_k, loss_k = exp.trainer.train(global_params, it)
            sw.fence(p_k)        # measured walls feed ComputeModel
        walls.append(sw.dur_s)
        client_params.append(p_k)
        losses.append(loss_k)
        sizes.append(len(ds_k))
    weights = np.asarray(sizes, np.float32)
    return (client_params, weights / weights.sum(),
            float(np.mean(losses)), np.asarray(walls, np.float64))


def run_experiment(exp: FLExperiment, init_params: Any, rounds: int,
                   *, eval_every: int = 1, verbose: bool = False
                   ) -> list[RoundLog]:
    rng = np.random.default_rng(exp.seed)
    global_params = init_params
    logs: list[RoundLog] = []

    for t in range(rounds):
        with obs.timed("fl.round", cat="fl", round=t) as sw:
            client_params, weights, loss, _ = train_cohort(
                exp, rng, global_params)
            result = exp.strategy.aggregate(client_params, weights,
                                            global_params, rng)
            global_params = result.global_params

            acc = float("nan")
            if (t + 1) % eval_every == 0:
                acc = exp.eval_fn(global_params, exp.test_set.images,
                                  exp.test_set.labels)
            sw.fence((global_params, acc))
        logs.append(RoundLog(t, bool(result.decoded), result.n_aggregated,
                             loss, acc, sw.dur_s))
        if verbose:
            print(f"round {t:3d} decoded={result.decoded} "
                  f"loss={loss:.4f} acc={acc:.4f}")
    return logs


def final_accuracy(logs: list[RoundLog], k_last: int = 5) -> float:
    accs = [l.test_acc for l in logs if not np.isnan(l.test_acc)]
    if not accs:
        return float("nan")
    return float(np.mean(accs[-k_last:]))
