"""FL substrate: local training, server strategies, round orchestration."""
from .client import LocalTrainer
from .rounds import FLExperiment, RoundLog, run_experiment
from .server import (FedAvgStrategy, FedNCStrategy,
                     HierarchicalFedNCStrategy)

__all__ = [
    "LocalTrainer", "FLExperiment", "RoundLog", "run_experiment",
    "FedAvgStrategy", "FedNCStrategy", "HierarchicalFedNCStrategy",
]
