"""FL substrate: local training, server strategies, round orchestration
(lockstep and async/simulated-clock variants)."""
from .async_rounds import (AsyncRoundLog, blind_box_schedule,
                           run_async_experiment)
from .client import LocalTrainer
from .rounds import FLExperiment, RoundLog, run_experiment
from .server import (AsyncFedNCStrategy, FedAvgStrategy, FedNCStrategy,
                     HierarchicalFedNCStrategy)

__all__ = [
    "LocalTrainer", "FLExperiment", "RoundLog", "run_experiment",
    "AsyncRoundLog", "blind_box_schedule", "run_async_experiment",
    "AsyncFedNCStrategy", "FedAvgStrategy", "FedNCStrategy",
    "HierarchicalFedNCStrategy",
]
