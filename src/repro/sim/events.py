"""The vectorized event engine: one round's arrival stream.

A round's "events" are the packets the server hears, in the order it
hears them.  The generating model (documented in docs/simulator.md):

* The live cohort multicasts continuously; the server's g-th reception
  is sourced from a uniformly random live participant — exactly the
  paper §IV-A blind-box assumption, which is what makes the measured
  FedAvg draw count coupon-collector distributed and the FedNC one
  rank-K distributed (Prop. 1).
* The *gap* between consecutive receptions is an independent draw from
  the configured straggler distribution, stretched by the source's
  static slowness factor and divided by the number of live emitters
  (aggregate bandwidth grows with the cohort).  Heavy-tailed gaps are
  straggler stalls: the stream freezes while everyone waits on a slow
  uploader.
* An optional per-client *delay* distribution adds a one-per-client
  latency offset and re-sorts — packets from slow clients arrive late
  and out of emission order.  This leaves the blind-box regime (the
  arrival-order source sequence is no longer i.i.d. uniform), which is
  the point: it is the knob Prop. 1 cannot see and only the simulator
  can measure.

Everything is a handful of O(G) numpy kernels — sample, cumsum,
argsort — never a Python loop over events.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .distributions import DistSpec


@dataclass
class RoundEvents:
    """One round's server-side arrival stream, in arrival order."""

    times: np.ndarray     # (G,) nondecreasing simulated clock
    sources: np.ndarray   # (G,) cohort-local source index in [0, k)
    live: np.ndarray      # (k,) bool — which cohort members transmit

    @property
    def n_events(self) -> int:
        return int(self.times.shape[0])

    def first_arrival_index(self) -> np.ndarray:
        """(k,) index of each cohort member's first arrival (n_events
        where it never arrives — dropped clients, short streams)."""
        k = self.live.shape[0]
        first = np.full(k, self.n_events, dtype=np.int64)
        np.minimum.at(first, self.sources,
                      np.arange(self.n_events, dtype=np.int64))
        return first


def arrival_stream(rng: np.random.Generator, live: np.ndarray,
                   slowness: np.ndarray, gap: DistSpec,
                   n_events: int,
                   delay: Optional[DistSpec] = None) -> RoundEvents:
    """Build one round's arrival stream of `n_events` receptions.

    `live` is the (k,) transmit mask, `slowness` the (k,) per-client
    static factors.  Dead clients are never drawn as sources.
    """
    live = np.asarray(live, bool)
    k = live.shape[0]
    live_idx = np.nonzero(live)[0]
    k_live = int(live_idx.shape[0])
    if k_live == 0 or n_events == 0:
        return RoundEvents(np.zeros(0), np.zeros(0, np.int64), live)
    sources = live_idx[rng.integers(0, k_live, size=n_events)]
    gaps = gap.sample(rng, n_events) * slowness[sources] / k_live
    times = np.cumsum(gaps)
    if delay is not None:
        offsets = delay.sample(rng, k)
        times = times + offsets[sources]
        order = np.argsort(times, kind="stable")
        times, sources = times[order], sources[order]
    return RoundEvents(times, sources, live)
