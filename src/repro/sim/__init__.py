"""repro.sim — vectorized event-driven FL network simulator.

The round loop in `federation/rounds.py` is lockstep: every client
uploads, the server aggregates, the clock does not exist.  FedNC's
efficiency and robustness claims are *temporal* — Prop. 1 is about how
many arrivals the server must wait for — so this package simulates the
missing axis: per-client compute/bandwidth heterogeneity, straggler
tails, dropout and churn, partial participation, and the arrival-order
stream the server actually hears.

distributions.py — named delay distributions (constant, exponential,
                   lognormal, pareto) normalized to a common mean so
                   straggler tails are comparable; a registry for
                   custom ones.
population.py    — ClientPopulation: static per-client speed factors
                   over millions of clients, churn-aware cohort
                   sampling, dropout injection.
events.py        — the vectorized event engine: one round's arrival
                   stream (times, sources) as a handful of numpy
                   kernels, never a Python-per-event loop.
simulator.py     — NetworkSimulator: runs FedNC (stop at rank K via
                   `engine.stream.StreamDecoder`) and FedAvg (wait for
                   every cohort member) against the *same* arrival
                   stream, producing per-round draw counts and
                   simulated-clock decode times.

See docs/simulator.md for the event model and the Prop.-1 validation.
"""
from .compute import ComputeModel
from .distributions import (STRAGGLER_PROFILES, DistSpec,
                            register_distribution, sample_delays)
from .events import RoundEvents, arrival_stream
from .population import ClientPopulation, PopulationConfig
from .simulator import NetworkSimulator, RoundStats, SimConfig, SimTrace

__all__ = [
    "ComputeModel", "DistSpec", "STRAGGLER_PROFILES",
    "register_distribution", "sample_delays", "RoundEvents",
    "arrival_stream", "ClientPopulation", "PopulationConfig",
    "NetworkSimulator", "RoundStats", "SimConfig", "SimTrace",
]
