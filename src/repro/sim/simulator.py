"""NetworkSimulator: FedNC vs FedAvg against the same arrival stream.

Each simulated round:

1. **Cohort** — `clients_per_round` distinct online clients sampled
   from the population (churned invitations are replaced and counted);
   each participant independently *drops out* with `p_dropout` and
   then never transmits.
2. **Stream** — the event engine builds the round's arrival stream
   (times + sources) from the configured straggler gap distribution
   and the cohort's static slowness factors.
3. **FedNC** — the server feeds arrivals to a
   :class:`repro.engine.stream.StreamDecoder` (real GF(2^s) rank
   evolution, one `lax.scan` dispatch per round) and stops at rank
   K_live: `fednc_draws` arrivals, `fednc_time` on the simulated
   clock.  For cohorts too large to carry a K×K basis, the
   ``stages`` decoder samples the identical rank-evolution law —
   draw g is useful with probability 1 − q^(r−K) — as K geometric
   stages (see docs/simulator.md for the equivalence).
4. **FedAvg** — the blind-box collector: the server is done when every
   cohort member has been heard at least once.  A single dropout
   blocks it forever (`fedavg_complete=False`, it waits until
   `timeout`); FedNC just decodes the survivors.

Determinism: everything flows from one `np.random.Generator(seed)`,
so equal seeds give bit-identical traces (tested).  Per-round work is
O(G) numpy + one scan dispatch, G ≈ K·H(K); populations are O(N) once
— 10^6 clients × 100 rounds runs in seconds on CPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro import obs
from repro.core.coupon import expected_draws_fedavg_asymptotic

from .distributions import DistSpec
from .events import arrival_stream
from .population import ClientPopulation, PopulationConfig


@dataclass(frozen=True)
class SimConfig:
    population: PopulationConfig = field(
        default_factory=PopulationConfig)
    clients_per_round: int = 64
    s: int = 8                    # GF(2^s) of the coded packets
    gap: DistSpec = field(default_factory=DistSpec)   # stream gaps
    delay: Optional[DistSpec] = None   # per-client reorder offsets
    decoder: str = "auto"         # "stream" | "stages" | "auto"
    timeout: float = math.inf     # simulated seconds per round
    seed: int = 0

    # cohorts above this run the geometric-stage rank law instead of
    # carrying a K x K GF basis through the StreamDecoder
    stream_decoder_max_k: int = 512


@dataclass
class RoundStats:
    """One round's measured outcome (simulated clock + draw counts)."""

    round: int
    k: int                  # cohort size
    k_live: int             # cohort members that actually transmit
    n_dropped: int
    n_churned: int
    fednc_draws: int        # arrivals until rank K_live (Prop. 1, measured)
    fednc_time: float       # simulated clock at decode
    fednc_decoded: bool
    fedavg_draws: int       # arrivals until every cohort member heard
    fedavg_time: float
    fedavg_complete: bool
    fedavg_heard: int       # distinct sources heard by completion/timeout


@dataclass
class SimTrace:
    """The per-round stats of one simulation run."""

    config: SimConfig
    rounds: list[RoundStats] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.rounds)

    def column(self, name: str) -> np.ndarray:
        return np.asarray([getattr(r, name) for r in self.rounds])

    def summary(self) -> dict:
        """Aggregate means; the draw ratio uses only rounds where both
        collectors finished (under dropout FedAvg never does)."""
        both = [r for r in self.rounds
                if r.fednc_decoded and r.fedavg_complete]
        out = {
            "rounds": len(self.rounds),
            "k": self.config.clients_per_round,
            "population": self.config.population.n_clients,
            "fednc_decode_rate": float(np.mean(
                self.column("fednc_decoded"))) if self.rounds else 0.0,
            "fedavg_complete_rate": float(np.mean(
                self.column("fedavg_complete"))) if self.rounds else 0.0,
            "n_dropped_mean": float(np.mean(
                self.column("n_dropped"))) if self.rounds else 0.0,
        }
        if both:
            nc = np.asarray([r.fednc_draws for r in both], float)
            avg = np.asarray([r.fedavg_draws for r in both], float)
            t_nc = np.asarray([r.fednc_time for r in both])
            t_avg = np.asarray([r.fedavg_time for r in both])
            out.update(
                fednc_draws_mean=float(nc.mean()),
                fedavg_draws_mean=float(avg.mean()),
                draw_ratio=float(avg.mean() / nc.mean()),
                time_to_rank_k_mean=float(t_nc.mean()),
                time_to_all_k_mean=float(t_avg.mean()),
                time_to_rank_k_p50=float(np.median(t_nc)),
                time_to_all_k_p50=float(np.median(t_avg)),
                time_speedup=float(t_avg.mean() / t_nc.mean()),
            )
        return out


_DEFAULT_CONFIG = SimConfig()    # shared default (ruff B008)


class NetworkSimulator:
    """Event-driven FL network simulation for one SimConfig."""

    def __init__(self, config: SimConfig = _DEFAULT_CONFIG):
        self.config = config
        self.population = ClientPopulation(config.population,
                                           seed=config.seed)
        k = config.clients_per_round
        if config.decoder == "stream":
            self._use_stream = True
        elif config.decoder == "stages":
            self._use_stream = False
        elif config.decoder == "auto":
            self._use_stream = k <= config.stream_decoder_max_k
        else:
            raise ValueError(f"unknown decoder {config.decoder!r}")
        m = self.metrics = obs.MetricsRegistry()
        self._m_rounds = m.counter("sim.rounds")
        self._m_nc_draws = m.counter("sim.fednc_draws")
        self._m_avg_draws = m.counter("sim.fedavg_draws")
        self._m_dropped = m.counter("sim.dropped")

    # -- per-round pieces -------------------------------------------------

    def _fednc_draws_stream(self, rng: np.random.Generator,
                            live: np.ndarray, horizon: int
                            ) -> Optional[int]:
        """Measured rank evolution: feed fresh uniform coded vectors
        (support = live cohort columns) to a StreamDecoder; return the
        arrival count reaching rank K_live (None: not within horizon).

        Blind-box metadata per arrival is a 4-byte uint32 row seed —
        the wire format of the seeded kernel family — not a K-symbol
        row: the StreamDecoder regenerates each row inside its jitted
        scan and masks dropout columns there (``col_mask``), so the
        simulator never materializes a (prefix, K) coefficient block
        host-side.  Determinism by SimConfig.seed is preserved (seeds
        come from the same per-round Generator)."""
        from repro.engine.stream import StreamDecoder
        k = live.shape[0]
        k_live = int(live.sum())
        prefix = min(horizon, k + 32)
        seeds = rng.integers(0, 1 << 32, size=prefix, dtype=np.uint32)
        dec = StreamDecoder(K=k, L=0, s=self.config.s)
        ranks = dec.ingest_seeded(seeds, col_mask=live)
        hit = np.nonzero(ranks >= k_live)[0]
        if hit.size == 0:
            return None
        return int(hit[0]) + 1

    def _fednc_draws_stages(self, rng: np.random.Generator,
                            k_live: int) -> int:
        """The same rank-evolution law, sampled: stage r -> r+1 takes
        Geom(1 - q^(r-K)) draws (a uniform vector escapes an r-dim
        subspace of F_q^K with exactly that probability)."""
        q = float(1 << self.config.s)
        p = 1.0 - q ** (np.arange(k_live, dtype=np.float64) - k_live)
        return int(rng.geometric(p).sum())

    def _round(self, t: int, rng: np.random.Generator) -> RoundStats:
        cfg = self.config
        k = cfg.clients_per_round
        cohort, n_churned = self.population.sample_cohort(rng, k)
        live = self.population.dropout_mask(rng, k)
        k_live = int(live.sum())
        n_dropped = k - k_live
        slowness = self.population.slowness[cohort]

        if k_live == 0:
            return RoundStats(t, k, 0, n_dropped, n_churned,
                              0, math.inf, False,
                              0, math.inf, False, 0)

        # -- build a stream long enough for both collectors ------------
        # E[FedAvg draws] = K·H(K) (paper eq. 5 via core.coupon) + slack
        n0 = int(1.6 * expected_draws_fedavg_asymptotic(k_live)) + 64
        while True:
            ev = arrival_stream(rng, live, slowness, cfg.gap,
                                n_events=n0, delay=cfg.delay)
            first = ev.first_arrival_index()
            live_first = first[live]
            # FedNC: measured (stream) or sampled (stages) draw count
            if self._use_stream:
                g_nc = self._fednc_draws_stream(rng, live, n0)
            else:
                g_nc = self._fednc_draws_stages(rng, k_live)
                if g_nc > n0:
                    g_nc = None
            if g_nc is not None and (n_dropped > 0
                                     or (live_first < n0).all()):
                break
            n0 *= 2     # rare: straggler-heavy round outran the horizon

        fednc_time = float(ev.times[g_nc - 1])
        fednc_decoded = fednc_time <= cfg.timeout

        # -- FedAvg: the all-K wait ------------------------------------
        if n_dropped == 0:
            g_avg = int(live_first.max()) + 1
            t_avg = float(ev.times[g_avg - 1])
            complete = t_avg <= cfg.timeout
        else:
            complete = False
            t_avg = cfg.timeout   # blocks on the missing coupon
        if complete:
            heard = k_live
            draws = g_avg
        else:
            horizon_t = min(cfg.timeout, float(ev.times[-1]))
            arrived = live_first < ev.n_events
            heard_t = np.where(arrived, ev.times[
                np.minimum(live_first, ev.n_events - 1)], math.inf)
            heard = int((heard_t <= horizon_t).sum())
            draws = int((ev.times <= horizon_t).sum())
            t_avg = cfg.timeout if math.isfinite(cfg.timeout) \
                else math.inf

        return RoundStats(t, k, k_live, n_dropped, n_churned,
                          int(g_nc), fednc_time, bool(fednc_decoded),
                          int(draws), float(t_avg), bool(complete),
                          heard)

    # -- the run ----------------------------------------------------------

    def run(self, rounds: int) -> SimTrace:
        """Simulate `rounds` rounds; deterministic in `config.seed`."""
        rng = np.random.default_rng(self.config.seed)
        trace = SimTrace(self.config)
        tr = obs.get_tracer()
        for t in range(rounds):
            with tr.span("sim.round", cat="sim", round=t):
                stats = self._round(t, rng)
            trace.rounds.append(stats)
            self._m_rounds.inc()
            self._m_nc_draws.inc(stats.fednc_draws)
            self._m_avg_draws.inc(stats.fedavg_draws)
            self._m_dropped.inc(stats.n_dropped)
            if tr.enabled:
                tr.instant("sim.decode", cat="sim", round=t,
                           draws=stats.fednc_draws,
                           sim_time=stats.fednc_time)
                if stats.fedavg_complete:
                    tr.instant("sim.fedavg_complete", cat="sim",
                               round=t, draws=stats.fedavg_draws)
        return trace
