"""Named delay distributions for the network simulator.

Every distribution is normalized so its mean equals ``scale`` (when
the mean exists) — swapping a light tail for a heavy one changes the
*shape* of waiting, not the average load, which is what makes
time-to-decode comparisons across straggler profiles meaningful:

* ``constant``     — degenerate (scale exactly).
* ``exponential``  — memoryless baseline; the blind-box multicast of
                     paper §IV-A is exactly this regime.
* ``lognormal``    — the classic compute-straggler tail
                     (exp(σZ − σ²/2)·scale); ``shape`` is σ.
* ``pareto``       — heavy tail (Lomax, normalized); ``shape`` is α.
                     α ≤ 1 has infinite mean — legal, the simulator
                     measures medians too, but the bundled profiles
                     keep α > 1.

Custom distributions register by name (`register_distribution`), same
pattern as the engine's kernel registry.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np

# name -> sampler(rng, size, scale, shape) returning float64 ndarray
_SAMPLERS: Dict[str, Callable] = {}


def register_distribution(name: str, sampler: Callable) -> None:
    """Register ``sampler(rng, size, scale, shape) -> np.ndarray``."""
    _SAMPLERS[name] = sampler


def available_distributions() -> list[str]:
    return sorted(_SAMPLERS)


register_distribution(
    "constant", lambda rng, size, scale, shape: np.full(size, scale))
register_distribution(
    "exponential", lambda rng, size, scale, shape:
    rng.exponential(scale, size=size))
register_distribution(
    "lognormal", lambda rng, size, scale, shape:
    scale * rng.lognormal(mean=-0.5 * shape * shape, sigma=shape,
                          size=size))
register_distribution(
    "pareto", lambda rng, size, scale, shape:
    scale * max(shape - 1.0, 0.0) * rng.pareto(shape, size=size)
    if shape > 1.0 else scale * rng.pareto(shape, size=size))


@dataclass(frozen=True)
class DistSpec:
    """A named delay distribution with its scale and shape parameter.

    ``shape`` is σ for lognormal, α for pareto, ignored otherwise.
    Frozen/hashable so it can sit inside SimConfig.
    """

    name: str = "exponential"
    scale: float = 1.0
    shape: float = 1.0

    def sample(self, rng: np.random.Generator, size) -> np.ndarray:
        return sample_delays(self, rng, size)


def sample_delays(spec: DistSpec, rng: np.random.Generator,
                  size) -> np.ndarray:
    """Draw `size` delays from `spec` (vectorized, host numpy)."""
    try:
        sampler = _SAMPLERS[spec.name]
    except KeyError:
        raise KeyError(
            f"unknown distribution {spec.name!r}; registered: "
            f"{available_distributions()}") from None
    return np.asarray(sampler(rng, size, float(spec.scale),
                              float(spec.shape)), dtype=np.float64)


# The straggler profiles the benchmarks sweep: same unit mean,
# increasingly heavy upper tails.
STRAGGLER_PROFILES: Dict[str, DistSpec] = {
    "constant": DistSpec("constant", 1.0, 0.0),
    "exponential": DistSpec("exponential", 1.0, 0.0),
    "lognormal": DistSpec("lognormal", 1.0, 1.0),
    "pareto": DistSpec("pareto", 1.0, 1.5),
}
