"""Per-client local-training compute time for the arrival clock.

The async round driver historically trained clients synchronously and
only *then* simulated network arrivals — the simulated clock saw the
network but not the computation feeding it.  A :class:`ComputeModel`
closes the loop: it produces one simulated compute time per cohort
member, and the async strategy adds that client's time to every packet
it sources, so a fast client's packets genuinely arrive while a slow
client is still training.

Two modes, matching how real FL systems estimate device speed:

* **modeled** (default) — per-client work is an i.i.d. draw from a
  `repro.sim` distribution (a FLOP-count proxy; unit-mean lognormal by
  default, the classic compute-straggler tail) divided by
  ``flops_per_second``.
* **measured** — ``measured_scale > 0`` rescales the *actual* wall
  seconds each client's local training took (collected by
  ``federation.rounds.train_cohort``) into simulated seconds, so the
  schedule reflects the real heterogeneity of the training run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .distributions import DistSpec


@dataclass(frozen=True)
class ComputeModel:
    """How long each cohort member computes before it can transmit."""

    # per-client work draw (FLOP proxy; unit mean keeps profiles
    # comparable, same convention as the straggler distributions)
    work: DistSpec = field(default_factory=lambda: DistSpec(
        "lognormal", 1.0, 0.5))
    flops_per_second: float = 1.0
    # > 0: ignore `work` and rescale measured training wall seconds
    measured_scale: float = 0.0

    def times(self, rng: np.random.Generator, k: int,
              measured_wall: Optional[np.ndarray] = None) -> np.ndarray:
        """(k,) strictly-positive simulated compute seconds."""
        if self.measured_scale > 0.0:
            if measured_wall is None:
                raise ValueError(
                    "measured_scale > 0 needs measured_wall times")
            t = np.asarray(measured_wall, np.float64) * self.measured_scale
        else:
            if self.flops_per_second <= 0.0:
                raise ValueError("flops_per_second must be positive")
            t = self.work.sample(rng, k) / self.flops_per_second
        # a zero compute time would make "strictly later than the
        # network-only schedule" vacuous; clamp to a tick
        return np.maximum(t, np.finfo(np.float64).tiny)
