"""Client populations: millions of heterogeneous clients, cheap cohorts.

A population is a static vector of per-client *speed factors* (drawn
once, seed-deterministic) plus the two failure knobs of real FL
fleets:

* **churn** (`p_churn`)   — a client is offline at selection time; the
                            server notices immediately and invites a
                            replacement, so cohorts stay full but the
                            sampler does extra work.
* **dropout** (`p_dropout`) — a *selected* participant silently fails
                            mid-round: it trains (or not) but its
                            packets never arrive, and the server only
                            finds out by waiting.  This is the failure
                            mode that separates FedNC (decodes the
                            survivors at rank K_live) from FedAvg
                            (blocks on the missing coupon forever).

Everything is numpy-vectorized: init is O(N) once, each cohort draw is
O(k) expected, so 10^6 clients cost ~8 MB and nothing per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .distributions import DistSpec


@dataclass(frozen=True)
class PopulationConfig:
    n_clients: int = 1000
    # static per-client slowness multiplier (bandwidth/compute mix);
    # normalized to unit mean at init so the gap scale stays the unit
    speed: DistSpec = field(default_factory=lambda: DistSpec(
        "lognormal", 1.0, 0.5))
    p_churn: float = 0.0
    p_dropout: float = 0.0


class ClientPopulation:
    """Static heterogeneity + cohort sampling for one population."""

    def __init__(self, config: PopulationConfig, seed: int = 0):
        if config.n_clients < 1:
            raise ValueError("population needs at least one client")
        self.config = config
        rng = np.random.default_rng(seed)
        slowness = config.speed.sample(rng, config.n_clients)
        mean = float(slowness.mean())
        if mean > 0:
            slowness = slowness / mean     # unit-mean normalization
        self.slowness = slowness.astype(np.float64)

    @property
    def n_clients(self) -> int:
        return self.config.n_clients

    def sample_cohort(self, rng: np.random.Generator, k: int
                      ) -> tuple[np.ndarray, int]:
        """Sample k distinct *online* clients (partial participation).

        Returns ``(indices, n_churned)`` — the cohort plus how many
        invitations bounced off churned-away clients.  Expected O(k)
        regardless of population size: candidates are drawn with
        replacement and deduplicated, so no O(N) permutation ever runs.
        """
        N = self.n_clients
        if k > N:
            raise ValueError(f"cohort {k} exceeds population {N}")
        p_churn = self.config.p_churn
        if p_churn >= 1.0:
            raise ValueError("p_churn >= 1: nobody is ever online")
        chosen: list[int] = []
        seen: set[int] = set()
        n_churned = 0
        while len(chosen) < k:
            if len(seen) >= N:
                raise RuntimeError(
                    f"churn left fewer than {k} of {N} clients online "
                    "this round")
            want = max(2 * (k - len(chosen)) + 8, 16)
            cand = rng.integers(0, N, size=want)
            online = rng.random(want) >= p_churn
            for c, ok in zip(cand.tolist(), online.tolist(),
                             strict=True):
                if c in seen:
                    continue
                seen.add(c)
                if not ok:
                    n_churned += 1
                    continue
                chosen.append(c)
                if len(chosen) == k:
                    break
        return np.asarray(chosen, dtype=np.int64), n_churned

    def dropout_mask(self, rng: np.random.Generator, k: int) -> np.ndarray:
        """(k,) bool — True where the participant actually transmits."""
        return rng.random(k) >= self.config.p_dropout
