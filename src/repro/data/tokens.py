"""Synthetic LM token streams for the large-architecture drivers.

Markov-chain token source with a planted bigram structure so language
models have real signal to fit (loss decreases measurably within a few
hundred steps even at toy scale).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenStream:
    vocab_size: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse preferred-successor table: each token strongly prefers
        # a handful of successors (planted structure)
        self._succ = rng.integers(0, self.vocab_size,
                                  size=(self.vocab_size, 4))
        self._rng = np.random.default_rng(self.seed + 1)

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        out[:, 0] = self._rng.integers(0, self.vocab_size, size=batch)
        for t in range(seq_len):
            prev = out[:, t]
            choice = self._rng.integers(0, 4, size=batch)
            planted = self._succ[prev, choice]
            noise = self._rng.integers(0, self.vocab_size, size=batch)
            use_noise = self._rng.random(batch) < 0.1
            out[:, t + 1] = np.where(use_noise, noise, planted)
        return out

    def batch(self, batch: int, seq_len: int) -> dict:
        toks = self.sample(batch, seq_len)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_token_stream(vocab_size: int, seed: int = 0) -> TokenStream:
    return TokenStream(vocab_size=vocab_size, seed=seed)
