"""FL data partitioners — paper §IV-A.2 data splitting.

* iid: the training set is randomly assigned; every client holds data
  of uniform categories.
* mixed non-iid: the set is divided into single-category shards; each
  client gets 2 shards (2 categories) except for a 5% iid part.
"""
from __future__ import annotations

import numpy as np


def iid_partition(labels: np.ndarray, num_clients: int, *, seed: int = 0
                  ) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(labels))
    return [np.sort(chunk) for chunk in np.array_split(order, num_clients)]


def mixed_noniid_partition(labels: np.ndarray, num_clients: int, *,
                           shards_per_client: int = 2,
                           iid_fraction: float = 0.05,
                           seed: int = 0) -> list[np.ndarray]:
    """Paper's 'mixed non-iid': 1-category shards, 2 per client,
    except for the 5% iid portion that is spread uniformly."""
    rng = np.random.default_rng(seed)
    n = len(labels)
    idx = rng.permutation(n)

    n_iid = int(round(iid_fraction * n))
    iid_idx, shard_idx = idx[:n_iid], idx[n_iid:]

    # sort the non-iid part by label -> contiguous single-category runs
    shard_idx = shard_idx[np.argsort(labels[shard_idx], kind="stable")]
    num_shards = num_clients * shards_per_client
    shards = np.array_split(shard_idx, num_shards)
    shard_order = rng.permutation(num_shards)

    iid_parts = np.array_split(rng.permutation(iid_idx), num_clients)

    out = []
    for c in range(num_clients):
        mine = [shards[shard_order[c * shards_per_client + j]]
                for j in range(shards_per_client)]
        mine.append(iid_parts[c])
        out.append(np.sort(np.concatenate(mine)))
    return out


def client_weights(partitions: list[np.ndarray]) -> np.ndarray:
    """p_k proportional to local dataset size (paper eq. 1)."""
    sizes = np.array([len(p) for p in partitions], np.float64)
    return (sizes / sizes.sum()).astype(np.float32)
