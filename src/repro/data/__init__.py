"""Data pipelines: synthetic image/token sources + FL partitioners."""
from .partition import iid_partition, mixed_noniid_partition
from .synthetic import SyntheticImageDataset, make_image_dataset
from .tokens import TokenStream, make_token_stream

__all__ = [
    "iid_partition", "mixed_noniid_partition", "SyntheticImageDataset",
    "make_image_dataset", "TokenStream", "make_token_stream",
]
