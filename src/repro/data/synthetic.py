"""Synthetic class-conditional image data (CIFAR-10 stand-in).

The container is offline (DESIGN.md §3), so the paper's CIFAR-10 task
is replaced by a structured synthetic distribution with the same shape:
each of the 10 classes has a fixed random spatial template; samples are
template + per-sample smooth noise.  A small CNN reaches high accuracy
on it only by actually learning the class structure, and — crucially
for the paper's claims — the iid/non-iid *partitioning* behaviour is
identical to the real dataset's.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticImageDataset:
    images: np.ndarray   # (N, H, W, C) float32 in [0, 1]
    labels: np.ndarray   # (N,) int32

    def __len__(self) -> int:
        return len(self.labels)

    def subset(self, idx: np.ndarray) -> "SyntheticImageDataset":
        return SyntheticImageDataset(self.images[idx], self.labels[idx])


def make_image_dataset(n: int, *, num_classes: int = 10, size: int = 32,
                       channels: int = 3, noise: float = 0.35,
                       seed: int = 0,
                       template_seed: int = 1234) -> SyntheticImageDataset:
    # class templates come from template_seed so that train/test splits
    # built with different sampling seeds share one distribution
    trng = np.random.default_rng(template_seed)
    base = trng.normal(size=(num_classes, size // 4, size // 4, channels))
    templates = base.repeat(4, axis=1).repeat(4, axis=2)
    templates = templates / (np.abs(templates).max() + 1e-9)

    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    imgs = templates[labels]
    imgs = imgs + noise * rng.normal(size=imgs.shape)
    imgs = (imgs - imgs.min()) / (imgs.max() - imgs.min() + 1e-9)
    return SyntheticImageDataset(imgs.astype(np.float32), labels)


def batches(ds: SyntheticImageDataset, batch_size: int, *, seed: int = 0,
            epochs: int = 1):
    """Shuffled minibatch iterator (drops the ragged tail)."""
    rng = np.random.default_rng(seed)
    n = len(ds)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i: i + batch_size]
            yield ds.images[idx], ds.labels[idx]
