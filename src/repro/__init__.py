"""repro: FedNC (network-coded federated learning) as a production-grade
multi-pod JAX framework. See DESIGN.md for the system inventory."""
__version__ = "0.1.0"
