"""Hierarchical FedNC (paper §III: "one can utilize the structure of
hierarchical FL where local clients encode their parameters at trusted
edge servers before uploading them to the central server").

Topology: K clients partitioned across E edge servers.  Each edge
collects its clients' plain packets over the trusted local hop, emits
`n_e` random linear combinations of them — coding vectors live in the
GLOBAL client index space (support = that edge's clients) — and the
edges' coded tuples travel the untrusted WAN to the central server,
optionally re-coding on the way (MultiHopChannel).  The server stacks
everything it received and decodes all K originals at once when the
combined coding matrix reaches rank K.

Benefits over flat FedNC, all testable here:
  * clients never transmit over the open channel at all;
  * an edge can emit spare combinations (n_e > K_e) so WAN erasures
    are repaired without re-contacting clients;
  * eavesdroppers on the WAN face the same rank-K wall.

This module is a thin adapter over
:meth:`repro.engine.CodingEngine.multi_edge_round`, which runs the
whole edge tier — E local encodes, the WAN channel, and the decode —
as ONE fused chunk-streamed dispatch in the global coding-vector
space.  `per_edge_round_reference` keeps the historical E-dispatch
path as the bit-exactness oracle and benchmark baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import packets as pkt
from .fednc import FedNCConfig, RoundResult, _aggregate, engine_for
from .gf import get_field
from .rlnc import EncodedBatch


@dataclass(frozen=True)
class EdgeGroup:
    """Client indices served by one edge server."""
    client_ids: tuple


def partition_edges(K: int, num_edges: int) -> list[EdgeGroup]:
    ids = np.array_split(np.arange(K), num_edges)
    return [EdgeGroup(tuple(int(i) for i in grp)) for grp in ids]


def edge_encode(P: jnp.ndarray, edge: EdgeGroup, K: int, n_out: int,
                cfg: FedNCConfig, key) -> EncodedBatch:
    """One edge's mixing: n_out combinations of ITS clients' packets,
    with coding vectors embedded in the global K-client index space."""
    field_ = get_field(cfg.s)
    sub = P[jnp.asarray(edge.client_ids, jnp.int32)]      # (K_e, L)
    A_local = field_.random_elements(key, (n_out, len(edge.client_ids)))
    C = engine_for(cfg).encode(sub, A_local).C            # chunk-streamed
    A_global = jnp.zeros((n_out, K), jnp.uint8)
    A_global = A_global.at[:, jnp.asarray(edge.client_ids)].set(A_local)
    return EncodedBatch(A=A_global, C=C)


def per_edge_round_reference(P: jnp.ndarray, edges: Sequence[EdgeGroup],
                             cfg: FedNCConfig, key, *,
                             spare_per_edge: int = 0,
                             wan_channel=None):
    """The historical E-dispatch path: one engine `encode` re-entry per
    edge, stage-wise WAN, stage-wise decode.

    Kept as the bit-exactness oracle (and benchmark baseline) for the
    engine's fused :meth:`~repro.engine.CodingEngine.multi_edge_round`;
    consumes the identical PRNG/host-RNG streams.  Returns an
    EngineRound-shaped (ok, P_hat, report) triple."""
    from repro.engine.engine import EngineRound
    K = P.shape[0]
    engine = engine_for(cfg)
    batches = []
    for e, edge in enumerate(edges):
        n_out = len(edge.client_ids) + spare_per_edge
        batches.append(edge_encode(P, edge, K, n_out, cfg,
                                   jax.random.fold_in(key, e)))
    combined = batches[0]
    for b in batches[1:]:
        combined = combined.concat(b)

    report = None
    if wan_channel is not None:
        combined, report = wan_channel.transmit_encoded(combined, cfg.s)
        if not report.decodable:
            return EngineRound(False, None, report)
    if combined.n < K:
        return EngineRound(False, None, report)
    ok, P_hat = engine.decode(combined)
    return EngineRound(bool(ok), P_hat, report)


def hierarchical_fednc_round(client_params: Sequence[Any],
                             weights: Sequence[float],
                             prev_global: Any,
                             cfg: FedNCConfig, key, *,
                             num_edges: int = 2,
                             spare_per_edge: int = 0,
                             wan_channel=None,
                             fused: bool = True) -> RoundResult:
    """Full hierarchical round: client -> edge encode -> WAN -> server.

    Thin adapter over the engine: the default fused path runs the whole
    edge tier as ONE chunk-streamed dispatch
    (:meth:`repro.engine.CodingEngine.multi_edge_round`); ``fused=False``
    runs the per-edge reference (E engine re-entries + stage-wise WAN),
    bit-identical by construction — both draw edge e's mixing matrix
    from ``fold_in(key, e)`` and the WAN plan from the same host RNG.
    """
    K = len(client_params)
    P, spec = pkt.pytrees_to_packets(client_params, s=cfg.s)
    edges = partition_edges(K, num_edges)
    engine = engine_for(cfg)
    if fused:
        out = engine.multi_edge_round(
            P, key, [edge.client_ids for edge in edges],
            spare_per_edge=spare_per_edge, wan_channel=wan_channel)
    else:
        out = per_edge_round_reference(
            P, edges, cfg, key, spare_per_edge=spare_per_edge,
            wan_channel=wan_channel)
    if not out.ok:
        return RoundResult(prev_global, False, out.report, 0)
    agg = _aggregate(out.packets, spec, weights, cfg)
    return RoundResult(agg, True, out.report, K)
