"""Hierarchical FedNC (paper §III: "one can utilize the structure of
hierarchical FL where local clients encode their parameters at trusted
edge servers before uploading them to the central server").

Topology: K clients partitioned across E edge servers.  Each edge
collects its clients' plain packets over the trusted local hop, emits
`n_e` random linear combinations of them — coding vectors live in the
GLOBAL client index space (support = that edge's clients) — and the
edges' coded tuples travel the untrusted WAN to the central server,
optionally re-coding on the way (MultiHopChannel).  The server stacks
everything it received and decodes all K originals at once when the
combined coding matrix reaches rank K.

Benefits over flat FedNC, all testable here:
  * clients never transmit over the open channel at all;
  * an edge can emit spare combinations (n_e > K_e) so WAN erasures
    are repaired without re-contacting clients;
  * eavesdroppers on the WAN face the same rank-K wall.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import packets as pkt
from .fednc import FedNCConfig, RoundResult, decode_and_aggregate, engine_for
from .gf import get_field
from .rlnc import EncodedBatch


@dataclass(frozen=True)
class EdgeGroup:
    """Client indices served by one edge server."""
    client_ids: tuple


def partition_edges(K: int, num_edges: int) -> list[EdgeGroup]:
    ids = np.array_split(np.arange(K), num_edges)
    return [EdgeGroup(tuple(int(i) for i in grp)) for grp in ids]


def edge_encode(P: jnp.ndarray, edge: EdgeGroup, K: int, n_out: int,
                cfg: FedNCConfig, key) -> EncodedBatch:
    """One edge's mixing: n_out combinations of ITS clients' packets,
    with coding vectors embedded in the global K-client index space."""
    field_ = get_field(cfg.s)
    sub = P[jnp.asarray(edge.client_ids, jnp.int32)]      # (K_e, L)
    A_local = field_.random_elements(key, (n_out, len(edge.client_ids)))
    C = engine_for(cfg).encode(sub, A_local).C            # chunk-streamed
    A_global = jnp.zeros((n_out, K), jnp.uint8)
    A_global = A_global.at[:, jnp.asarray(edge.client_ids)].set(A_local)
    return EncodedBatch(A=A_global, C=C)


def hierarchical_fednc_round(client_params: Sequence[Any],
                             weights: Sequence[float],
                             prev_global: Any,
                             cfg: FedNCConfig, key, *,
                             num_edges: int = 2,
                             spare_per_edge: int = 0,
                             wan_channel=None) -> RoundResult:
    """Full hierarchical round: client -> edge encode -> WAN -> server."""
    K = len(client_params)
    P, spec = pkt.pytrees_to_packets(client_params, s=cfg.s)

    edges = partition_edges(K, num_edges)
    batches = []
    for e, edge in enumerate(edges):
        n_out = len(edge.client_ids) + spare_per_edge
        batches.append(edge_encode(P, edge, K, n_out, cfg,
                                   jax.random.fold_in(key, e)))
    combined = batches[0]
    for b in batches[1:]:
        combined = combined.concat(b)

    report = None
    if wan_channel is not None:
        combined, report = wan_channel.transmit_encoded(combined, cfg.s)
        if not report.decodable:
            return RoundResult(prev_global, False, report, 0)

    # decode_and_aggregate row-selects on-device when n > K and skips
    # the round itself when the combined matrix is rank-deficient.
    res = decode_and_aggregate(combined, spec, weights, prev_global, cfg)
    res.report = report
    return res
