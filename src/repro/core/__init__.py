"""FedNC core: the paper's contribution as composable JAX modules."""
from . import (channel, coupon, dist, fednc, gf, hierarchy, packets,
               rlnc, security)
from .fednc import FedNCConfig, RoundResult, fedavg_round, fednc_round
from .gf import ge_solve, get_field, rank
from .packets import packet_to_pytree, pytree_to_packet
from .rlnc import (EncodedBatch, SeededBatch, decode, encode,
                   encode_seeded, random_coding_matrix,
                   random_coding_seeds)
from . import seeds

__all__ = [
    "channel", "coupon", "dist", "fednc", "gf", "hierarchy",
    "packets", "rlnc", "seeds",
    "security", "FedNCConfig", "RoundResult", "fedavg_round",
    "fednc_round", "get_field", "ge_solve", "rank",
    "packet_to_pytree", "pytree_to_packet", "EncodedBatch",
    "SeededBatch", "decode", "encode", "encode_seeded",
    "random_coding_matrix", "random_coding_seeds",
]
