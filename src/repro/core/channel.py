"""Channel models for FedNC experiments (paper §III-A, §IV-A).

The container has no real network, so the paper's transmission effects
are simulated explicitly:

* `ErasureChannel`   — each uploaded packet is independently lost with
                       probability p (robustness claim, §III-A.3).
* `BlindBoxChannel`  — the server receives packets by random sampling
                       with replacement and "does not know where the
                       packet comes from" (paper §IV-A: "blind box
                       effect"; Prop. 1 coupon-collector setting).
* `MultiHopChannel`  — η network-interior links each re-code the
                       stream with fresh random coefficients (Prop. 2's
                       η; drives the decode-failure probability).
* `Eavesdropper`     — intercepts each transmitted tuple with
                       probability p; succeeds iff its intercepted
                       coding matrix reaches rank K (security claim).

All models operate on `EncodedBatch` (or plain packet matrices for the
FedAvg baseline) and use numpy RNG host-side — channel simulation is
control flow, not device math.

Channels whose effect is *linear in the row space* additionally expose
``plan_transform(n, s)``: the channel's whole action on n transmitted
tuples, decided up front (consuming exactly the same host RNG draws as
``transmit_encoded``) and returned as a :class:`RowGather` (erasures —
which rows survive; blind-box sampling — which rows are drawn, with
replacement) or :class:`RowMix` (recoding relays — the composed
mixing matrix).  The plan only touches the tiny row space, never the
L-sized payload, which lets `repro.engine.CodingEngine` fold the
channel into its chunk-streamed encode→decode dispatch instead of
materializing the full coded payload between stages.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .gf import get_field, rank as gf_rank
from .rlnc import EncodedBatch


@dataclass
class ChannelReport:
    """What happened during one round's transmission."""
    sent: int
    delivered: int
    decodable: bool
    distinct_sources: int = -1      # FedAvg bookkeeping under blind box


@dataclass
class AsyncChannelReport(ChannelReport):
    """ChannelReport plus the simulated clock: when (and after how
    many arrivals) an async server had what it needed."""
    consumed: int = -1              # arrivals until rank K (Prop. 1)
    sim_time: float = float("nan")  # simulated clock at decode
    # decode time of the network-only schedule (no compute coupling);
    # equals sim_time when no ComputeModel was in play
    sim_time_network: float = float("nan")


@dataclass(frozen=True)
class ArrivalSchedule:
    """Per-packet arrival times for n transmitted tuples.

    The schedule is the bridge between the network simulator (which
    produces times) and async consumers (which want packets in arrival
    order): `order` is the permutation that sorts transmission order
    into arrival order, and `time_of(g)` is the simulated clock after
    the g-th arrival.  Times may be any order — relays and per-client
    latency reorder packets; that is the point of scheduling arrivals
    instead of assuming transmission order.
    """

    times: np.ndarray

    @property
    def n(self) -> int:
        return int(np.asarray(self.times).shape[0])

    @functools.cached_property
    def order(self) -> np.ndarray:
        """Transmission-order indices sorted by arrival time (stable).
        Cached — consumers read it plus `time_of` per round, and the
        permutation answers both."""
        return np.argsort(np.asarray(self.times), kind="stable")

    def time_of(self, g: int) -> float:
        """Simulated clock once g arrivals have been heard (1-based)."""
        if not 1 <= g <= self.n:
            raise ValueError(f"arrival count {g} outside 1..{self.n}")
        return float(np.asarray(self.times)[self.order[g - 1]])

    def offset_by(self, offsets) -> "ArrivalSchedule":
        """A new schedule with per-packet `offsets` (transmission
        order) added to the times — how local-training compute couples
        into the clock: a packet cannot leave before its source client
        finished computing.  Re-sorting is free (`order` is derived),
        and with nonnegative offsets every order statistic of the new
        schedule weakly dominates the old one."""
        offsets = np.asarray(offsets, np.float64)
        times = np.asarray(self.times, np.float64)
        if offsets.shape != times.shape:
            raise ValueError(
                f"offsets shape {offsets.shape} != times {times.shape}")
        return ArrivalSchedule(times + offsets)


@dataclass(frozen=True)
class RowGather:
    """Channel plan: rows `idx` (host int array) survive, in order."""
    idx: np.ndarray


@dataclass(frozen=True)
class RowMix:
    """Channel plan: received tuples are R·(A, C) — a linear mix of the
    sent ones (network-interior recoding, Prop. 2)."""
    R: jnp.ndarray


@dataclass(frozen=True)
class RowTamper:
    """Channel plan: a byzantine interior node delivers all n tuples,
    but XORs rows ``idx`` with adversarial noise — uniform GF(2^s)
    symbols expanded from 4-byte counters (`repro.core.seeds`), so the
    plan itself stays tiny: the engine regenerates the error rows at
    the shapes it knows (K for coding rows, L for payloads) instead of
    shipping an L-sized error matrix.

    ``row_seeds``/``payload_seeds`` are (m,) uint32 or ``None``:
    XOR-with-uniform is replacement-by-uniform, so seeding only the
    payload models flipped symbols, only the row models a forged
    coding vector, and both models an arbitrarily hostile relay.
    Produced by :class:`repro.adversary.ByzantineChannel`."""
    idx: np.ndarray
    row_seeds: np.ndarray | None = None
    payload_seeds: np.ndarray | None = None

    @property
    def m(self) -> int:
        return int(np.asarray(self.idx).shape[0])


class ErasureChannel:
    """IID packet erasures with probability `p_erase`."""

    def __init__(self, p_erase: float, seed: int = 0):
        self.p_erase = float(p_erase)
        self.rng = np.random.default_rng(seed)

    def plan_transform(self, n: int, s: int) -> RowGather:
        """Decide the erasure pattern for n tuples (one RNG draw, the
        same stream `transmit_encoded` consumes)."""
        keep = self.rng.random(n) >= self.p_erase
        return RowGather(np.nonzero(keep)[0])

    def transmit_encoded(self, batch: EncodedBatch, s: int
                         ) -> tuple[EncodedBatch, ChannelReport]:
        idx = self.plan_transform(batch.n, s).idx
        out = batch[jnp.asarray(idx, jnp.int32)]
        dec = (len(idx) >= batch.K and
               int(gf_rank(get_field(s), out.A)) == batch.K)
        return out, ChannelReport(batch.n, len(idx), dec)

    def transmit_plain(self, packets: jnp.ndarray
                       ) -> tuple[jnp.ndarray, np.ndarray, ChannelReport]:
        """FedAvg baseline: returns (delivered, source_ids, report)."""
        K = packets.shape[0]
        keep = self.rng.random(K) >= self.p_erase
        idx = np.nonzero(keep)[0]
        rep = ChannelReport(K, len(idx), len(idx) == K,
                            distinct_sources=len(idx))
        return packets[jnp.asarray(idx, jnp.int32)], idx, rep


class BlindBoxChannel:
    """Random sampling with replacement: the Prop.-1 setting.

    The server draws `budget` packets; each draw is a uniformly random
    client (FedAvg) or a uniformly random *fresh coded* packet (FedNC —
    every coded packet is new, so any K with full rank decode).
    """

    def __init__(self, budget: int, seed: int = 0):
        self.budget = int(budget)
        self.rng = np.random.default_rng(seed)

    def plan_transform(self, n: int, s: int) -> RowGather:
        """The blind box as a row-space plan: the server's `budget`
        receptions are uniform draws *with replacement* from the n
        multicast tuples — a RowGather whose index vector may repeat
        rows (repeats are linearly dependent, so the engine's fused
        selector skips them exactly like the host-side oracle).
        Consumes one draw of the same RNG stream as `receive_plain` /
        `transmit_encoded`."""
        return RowGather(self.rng.integers(0, n, size=self.budget))

    def transmit_encoded(self, batch: EncodedBatch, s: int
                         ) -> tuple[EncodedBatch, ChannelReport]:
        """Stage-wise blind-box delivery of already-encoded tuples
        (the oracle for the fused `plan_transform` path)."""
        idx = self.plan_transform(batch.n, s).idx
        out = batch[jnp.asarray(idx, jnp.int32)]
        dec = (self.budget >= batch.K and
               int(gf_rank(get_field(s), out.A)) == batch.K)
        return out, ChannelReport(batch.n, self.budget, dec,
                                  distinct_sources=len(set(idx.tolist())))

    def receive_plain(self, packets: jnp.ndarray
                      ) -> tuple[jnp.ndarray, np.ndarray, ChannelReport]:
        """FedAvg: server gets `budget` draws w/ replacement; duplicate
        sources deliver duplicate packets."""
        K = packets.shape[0]
        draws = self.rng.integers(0, K, size=self.budget)
        distinct = len(set(draws.tolist()))
        rep = ChannelReport(self.budget, self.budget,
                            decodable=(distinct == K),
                            distinct_sources=distinct)
        return packets[jnp.asarray(draws, jnp.int32)], draws, rep

    def receive_encoded(self, make_coded, K: int, s: int
                        ) -> tuple[EncodedBatch, ChannelReport]:
        """FedNC: `make_coded(n)` yields n fresh random coded tuples
        (the network multicasts combinations; the server keeps the
        first `budget` it hears)."""
        batch = make_coded(self.budget)
        dec = (self.budget >= K and
               int(gf_rank(get_field(s), batch.A)) == K)
        return batch, ChannelReport(self.budget, self.budget, dec)


class MultiHopChannel:
    """η re-coding links between clients and server (Prop. 2).

    Each link draws a fresh random square recoding matrix over GF(2^s).
    The compose of η random matrices is singular with probability
    <= 1 - (1 - 2^-s)^η  (paper eq. 10 with d=1).
    """

    def __init__(self, eta: int, seed: int = 0):
        self.eta = int(eta)
        self.rng = np.random.default_rng(seed)

    def plan_transform(self, n: int, s: int) -> RowMix:
        """Compose the η hop matrices into one n×n mix (tiny, O(η·n³)
        field ops; the L-sized payload is untouched).  Consumes the
        same single host RNG draw as `transmit_encoded`."""
        import jax
        field = get_field(s)
        base = int(self.rng.integers(0, 2**31 - 1))
        R_comp = jnp.eye(n, dtype=jnp.uint8)
        for h in range(self.eta):
            R = field.random_elements(jax.random.PRNGKey(base + h),
                                      (n, n))
            R_comp = field.matmul(R, R_comp)
        return RowMix(R_comp)

    def transmit_encoded(self, batch: EncodedBatch, s: int, key=None,
                         engine=None) -> tuple[EncodedBatch, ChannelReport]:
        """η sequential recodes.  By linearity the hops compose:
        A' = (R_η···R_1)A, C' = (R_η···R_1)C — so the tiny n×n recode
        matrices are composed first (plan_transform) and the (huge)
        payload is recoded once through the engine's chunk-streamed
        kernel.  Bit-identical to hop-by-hop recoding.

        Pass `engine` to recode through a configured CodingEngine
        (kernel pin, chunking, mesh); the default resolves the 'auto'
        kernel for GF(2^s)."""
        if engine is None:
            from repro.engine import EngineConfig, get_engine
            engine = get_engine(EngineConfig(s=s))
        R_comp = self.plan_transform(batch.n, s).R
        out = engine.recode_with(R_comp, batch)
        dec = int(gf_rank(get_field(s), out.A)) == batch.K
        return out, ChannelReport(batch.n, out.n, dec)


class Eavesdropper:
    """Intercepts each tuple independently with probability p_intercept.

    * FedNC: learns nothing unless the intercepted coding matrix has
      rank K (then it can run the same GE the server runs).
    * FedAvg baseline: every intercepted packet IS a client's model —
      leak count = number of interceptions.
    """

    def __init__(self, p_intercept: float, seed: int = 0):
        self.p = float(p_intercept)
        self.rng = np.random.default_rng(seed)

    def attack_encoded(self, batch: EncodedBatch, s: int) -> dict:
        got = self.rng.random(batch.n) < self.p
        idx = np.nonzero(got)[0]
        if len(idx) == 0:
            return {"intercepted": 0, "rank": 0, "full_leak": False,
                    "partial_leak_packets": 0}
        sub = batch[jnp.asarray(idx, jnp.int32)]
        r = int(gf_rank(get_field(s), sub.A))
        full = r == batch.K
        return {
            "intercepted": int(len(idx)),
            "rank": r,
            "full_leak": bool(full),
            # under RLNC nothing decodes before full rank
            "partial_leak_packets": batch.K if full else 0,
        }

    def attack_plain(self, n_packets: int) -> dict:
        got = int((self.rng.random(n_packets) < self.p).sum())
        return {"intercepted": got, "rank": got,
                "full_leak": got == n_packets,
                "partial_leak_packets": got}
