"""Galois-field GF(2^s) arithmetic for RLNC, vectorized for JAX.

FedNC mixes model "packets" with coefficients drawn from GF(2^s)
(paper §II-B).  Symbols are s-bit values stored in uint8 (s <= 8).
Addition is XOR; multiplication uses log/antilog tables built from a
primitive polynomial of degree s.

The tables are built once per field size with numpy and cached; all
runtime ops are pure jnp and jit-safe.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# Primitive polynomials (with the x^s term) for GF(2^s), s = 1..8.
PRIMITIVE_POLY = {
    1: 0b11,          # x + 1
    2: 0b111,         # x^2 + x + 1
    3: 0b1011,        # x^3 + x + 1
    4: 0b10011,       # x^4 + x + 1
    5: 0b100101,      # x^5 + x^2 + 1
    6: 0b1000011,     # x^6 + x + 1
    7: 0b10000011,    # x^7 + x + 1
    8: 0b100011101,   # x^8 + x^4 + x^3 + x^2 + 1 (0x11D)
}


def _build_tables(s: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (exp, log) tables for GF(2^s) as uint8/int32 numpy arrays.

    exp has length 2*(q-1) so that exp[log a + log b] never needs a mod.
    log[0] is set to 0 but is meaningless (multiplication masks zeros).
    """
    if s not in PRIMITIVE_POLY:
        raise ValueError(f"unsupported field size s={s} (need 1..8)")
    q = 1 << s
    poly = PRIMITIVE_POLY[s]
    exp = np.zeros(max(2 * (q - 1), 1), dtype=np.uint8)
    log = np.zeros(q, dtype=np.int32)
    x = 1
    for i in range(q - 1):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & q:
            x ^= poly
    for i in range(q - 1, 2 * (q - 1)):
        exp[i] = exp[i - (q - 1)]
    if s == 1:  # q-1 == 1; exp table of len 2 with exp[0]=exp[1]=1
        exp = np.array([1, 1], dtype=np.uint8)
    return exp, log


@dataclass(frozen=True)
class GF:
    """A GF(2^s) field with jnp-resident lookup tables."""

    s: int
    exp: jnp.ndarray = field(repr=False)
    log: jnp.ndarray = field(repr=False)

    @property
    def q(self) -> int:
        return 1 << self.s

    @property
    def order(self) -> int:  # multiplicative group order
        return self.q - 1

    # ---- element-wise ops (broadcasting, uint8 in / uint8 out) ----

    def add(self, a, b):
        return jnp.bitwise_xor(a, b)

    sub = add  # characteristic 2

    def mul(self, a, b):
        a = jnp.asarray(a, jnp.uint8)
        b = jnp.asarray(b, jnp.uint8)
        la = jnp.take(self.log, a.astype(jnp.int32))
        lb = jnp.take(self.log, b.astype(jnp.int32))
        prod = jnp.take(self.exp, la + lb)
        mask = (a != 0) & (b != 0)
        return jnp.where(mask, prod, jnp.uint8(0))

    def inv(self, a):
        a = jnp.asarray(a, jnp.uint8)
        la = jnp.take(self.log, a.astype(jnp.int32))
        out = jnp.take(self.exp, (self.order - la) % self.order)
        return jnp.where(a == 0, jnp.uint8(0), out)  # inv(0) := 0 sentinel

    def div(self, a, b):
        return self.mul(a, self.inv(b))

    def pow(self, a, n: int):
        a = jnp.asarray(a, jnp.uint8)
        if n == 0:
            return jnp.ones_like(a)
        la = jnp.take(self.log, a.astype(jnp.int32))
        out = jnp.take(self.exp, (la * n) % self.order)
        return jnp.where(a == 0, jnp.uint8(0), out)

    # ---- linear algebra ----

    def matmul(self, A, B):
        """GF matrix product: A (n,k) @ B (k,m) -> (n,m), all uint8.

        Vectorized: one batched table-lookup multiply then an XOR
        reduction over k.  Memory O(n*k*m); the Pallas kernel in
        repro.kernels is the blocked production path.
        """
        A = jnp.asarray(A, jnp.uint8)
        B = jnp.asarray(B, jnp.uint8)
        prod = self.mul(A[:, :, None], B[None, :, :])  # (n,k,m)
        return xor_reduce(prod, axis=1)

    def matvec(self, A, x):
        return self.matmul(A, x[:, None])[:, 0]

    def random_elements(self, key, shape):
        """Uniform random field elements (including 0)."""
        return jax.random.randint(key, shape, 0, self.q, dtype=jnp.uint8)

    def random_nonzero(self, key, shape):
        r = jax.random.randint(key, shape, 1, max(self.q, 2), dtype=jnp.uint8)
        return r


def xor_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """XOR-reduction along an axis (jit-safe)."""
    return jax.lax.reduce(
        x, np.asarray(0, x.dtype), jax.lax.bitwise_xor, (axis,)
    )


@functools.lru_cache(maxsize=None)
def get_field(s: int) -> GF:
    # The first call may happen inside a jit / eval_shape trace (the
    # contract checker abstractly evaluates every registry kernel);
    # without escaping the trace, jnp.asarray would return tracers and
    # the lru_cache would leak them into every later concrete call.
    exp, log = _build_tables(s)
    with jax.ensure_compile_time_eval():
        return GF(s=s, exp=jnp.asarray(exp), log=jnp.asarray(log))


# ---------------------------------------------------------------------------
# Gaussian elimination over GF(2^s)
# ---------------------------------------------------------------------------

def ge_solve(field: GF, A, C):
    """Solve A @ X = C over GF(2^s) via Gaussian elimination.

    A: (K, K) uint8 coefficient matrix.
    C: (K, L) uint8 encoded packets.
    Returns (ok, X): ok is a scalar bool (A invertible), X is (K, L)
    uint8 (garbage when not ok).  jit-safe; K must be static.

    Partial pivoting means "pick any row with a non-zero entry" — GF has
    no rounding, so any non-zero pivot is exact.

    Dispatches through a per-field jit cache: called eagerly (the
    engine's decode planning path), the K-step elimination otherwise
    costs thousands of op-by-op dispatches — seconds at K=32.
    """
    return _ge_solve_fn(field.s)(jnp.asarray(A, jnp.uint8),
                                 jnp.asarray(C, jnp.uint8))


@functools.lru_cache(maxsize=None)
def _ge_solve_fn(s: int):
    field = get_field(s)

    @jax.jit
    def solve(A, C):
        return _ge_solve_traced(field, A, C)

    return solve


def _ge_solve_traced(field: GF, A, C):
    K = A.shape[0]
    M = jnp.concatenate([A, C], axis=1)  # (K, K+L) augmented
    ok = jnp.bool_(True)

    def body(col, state):
        M, ok = state
        colvals = M[:, col]
        rows = jnp.arange(K)
        candidates = (colvals != 0) & (rows >= col)
        piv = jnp.argmax(candidates)          # first valid pivot row
        ok = ok & candidates[piv]
        # swap rows `col` and `piv`
        row_c, row_p = M[col], M[piv]
        M = M.at[col].set(row_p).at[piv].set(row_c)
        # normalize pivot row
        pivval = M[col, col]
        # guard: if not ok pivval may be 0; inv(0)=0 keeps things finite
        M = M.at[col].set(field.mul(M[col], field.inv(pivval)))
        # eliminate this column from every other row
        factors = M[:, col]
        factors = factors.at[col].set(0)
        M = field.add(M, field.mul(factors[:, None], M[col][None, :]))
        return M, ok

    M, ok = jax.lax.fori_loop(0, K, body, (M, ok), unroll=True)
    return ok, M[:, K:]


def rank(field: GF, A) -> jnp.ndarray:
    """Rank of A (n, m) over GF(2^s). jit-safe, returns int32 scalar."""
    A = jnp.asarray(A, jnp.uint8)
    n, m = A.shape

    def body(col, state):
        M, r = state
        rows = jnp.arange(n)
        candidates = (M[:, col] != 0) & (rows >= r)
        piv = jnp.argmax(candidates)
        found = candidates[piv]

        def do_elim(M):
            row_r, row_p = M[r], M[piv]
            M2 = M.at[r].set(row_p).at[piv].set(row_r)
            pivval = M2[r, col]
            M2 = M2.at[r].set(field.mul(M2[r], field.inv(pivval)))
            factors = M2[:, col].at[r].set(0)
            return field.add(M2, field.mul(factors[:, None], M2[r][None, :]))

        M = jax.lax.cond(found, do_elim, lambda M: M, M)
        return M, r + found.astype(jnp.int32)

    _, r = jax.lax.fori_loop(0, m, body, (A, jnp.int32(0)))
    return r


def invert(field: GF, A):
    """(ok, A_inv) over GF(2^s)."""
    K = A.shape[0]
    I = jnp.eye(K, dtype=jnp.uint8)
    return ge_solve(field, A, I)
