"""Mesh-level FedNC: network coding as a TPU collective (DESIGN.md §3b).

Inside a pod, the paper's "clients" map onto the `data` axis of the
production mesh: each data-parallel group produces a model update, and
FedNC's random linear mixing is applied ACROSS that axis before the
(logical) server aggregates.  Coefficients live in the real field
(Gaussian: invertible a.s.) — the GF(2^s) bit-exact path remains the
WAN/protocol codec (core.rlnc).

Two formulations, identical math, very different wire cost:

* `mode='naive'` — paper-literal: all-gather every client's update
  (K× bytes), mix with the K×K matrix, decode (solve), average.
  Collective bytes/device ≈ K·L.  This is the faithful baseline.
* `mode='blocked'` — NC-aware reduce-scatter: updates are split into K
  blocks; one all-to-all lands block j of every client on device j,
  which encodes AND decodes that block locally, then an all-gather
  redistributes the averaged blocks.  Collective bytes/device ≈ 2·L —
  the same as a ring all-reduce: coding for free.  (§Perf hillclimb.)

Both return the exact FedAvg mean when decoding succeeds (linearity),
asserted by tests/test_dist.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map


def mix_matrix(key, K: int, dtype=jnp.float32) -> jnp.ndarray:
    """Random real coding matrix, shared by construction (same key)."""
    return jax.random.normal(key, (K, K), dtype)


def _naive_body(u, key, *, axis: str, K: int):
    """u: (L,) local update shard-of-clients; returns decoded mean."""
    A = mix_matrix(key, K)
    # 'upload': everyone hears everyone (paper server collects K packets)
    allu = jax.lax.all_gather(u, axis)            # (K, L)  K× wire bytes
    C = A @ allu.astype(jnp.float32)              # encode (eq. 4)
    P_hat = jnp.linalg.solve(A, C)                # GE decode
    return jnp.mean(P_hat, axis=0).astype(u.dtype)


def _blocked_body(u, key, *, axis: str, K: int):
    """NC-aware reduce-scatter formulation (bytes ≈ all-reduce)."""
    A = mix_matrix(key, K)
    L = u.shape[0]
    blocks = u.reshape(K, L // K)                  # block j for device j
    # all_to_all: device j ends with (K, L//K) = block j of every client
    mine = jax.lax.all_to_all(blocks, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    mine = mine.reshape(K, L // K)
    C = A @ mine.astype(jnp.float32)               # encode block j
    P_hat = jnp.linalg.solve(A, C)                 # decode block j
    mean_j = jnp.mean(P_hat, axis=0).astype(u.dtype)   # (L//K,)
    # redistribute averaged blocks to every device
    out = jax.lax.all_gather(mean_j, axis)         # (K, L//K)
    return out.reshape(L)


def fednc_mean_flat(u: jnp.ndarray, key, *, axis: str, K: int,
                    mode: str = "blocked") -> jnp.ndarray:
    """FedNC-coded mean of a flat per-device update, inside shard_map."""
    if mode == "naive":
        return _naive_body(u, key, axis=axis, K=K)
    if mode == "blocked":
        L = u.shape[0]
        pad = (-L) % K
        up = jnp.pad(u, (0, pad))
        out = _blocked_body(up, key, axis=axis, K=K)
        return out[:L]
    if mode == "psum":
        # beyond-paper algebraic fusion: decode∘encode = identity when
        # the channel is reliable — the entire codec collapses to the
        # mean (reference/fastest path; no coding on the wire).
        return jax.lax.pmean(u, axis)
    raise ValueError(f"unknown mode {mode!r}")


def fednc_tree_mean(tree: Any, key, *, axis: str, K: int,
                    mode: str = "blocked") -> Any:
    """Apply the coded mean leaf-wise to an update pytree (inside
    shard_map; each leaf is flattened, coded, averaged, restored)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        lkey = jax.random.fold_in(key, i)
        flat = leaf.reshape(-1)
        m = fednc_mean_flat(flat, lkey, axis=axis, K=K, mode=mode)
        out.append(m.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def make_fednc_mean(mesh: Mesh, *, axis: str = "data",
                    mode: str = "blocked"):
    """Host-level helper: returns f(update_tree, key) -> mean_tree with
    update sharded over `axis` (one 'client' update per axis index).

    update_tree leaves: (K, ...) with axis 0 sharded over `axis`.
    """
    K = mesh.shape[axis]

    def body(tree, key):
        # inside shard_map: leaves are (1, ...) local slices
        local = jax.tree_util.tree_map(lambda x: x[0], tree)
        mean = fednc_tree_mean(local, key, axis=axis, K=K, mode=mode)
        return jax.tree_util.tree_map(lambda x: x[None], mean)

    in_spec = (P(axis), P())
    out_spec = P(axis)
    try:
        return shard_map(body, mesh=mesh, in_specs=in_spec,
                         out_specs=out_spec, check_vma=False)
    except TypeError:  # older jax: check_rep instead of check_vma
        return shard_map(body, mesh=mesh, in_specs=in_spec,
                         out_specs=out_spec, check_rep=False)
