"""Packetization: model-parameter pytrees <-> GF(2^s) symbol packets.

The paper treats "the local parameters uploaded by each client as a
packet" (§III).  It leaves the real-number -> finite-field mapping out
of scope; we implement it two ways:

* **bit-exact** (default): float32 (or any dtype) leaves are bitcast to
  raw bytes; bytes are split into s-bit symbols.  RLNC over GF(2^s) is
  then *lossless* — decode returns the packet bit-for-bit.
* **quantized** (the paper's cited alternative [22]): per-tensor affine
  int8 quantization before byte-packing (lossy, 4x smaller packets).

A packet is a 1-D uint8 array of symbols (each in [0, 2^s)) plus a
`PacketSpec` describing how to reassemble the pytree.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PacketSpec:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    s: int
    n_bytes: int          # total byte length before symbol split
    quantized: bool = False

    @property
    def symbols_per_byte(self) -> int:
        return 8 // self.s if self.s < 8 else 1

    @property
    def n_symbols(self) -> int:
        return self.n_bytes * self.symbols_per_byte


# ---------------------------------------------------------------------------
# bytes <-> symbols
# ---------------------------------------------------------------------------

def bytes_to_symbols(b: jnp.ndarray, s: int) -> jnp.ndarray:
    """Split a uint8 byte stream into s-bit symbols (s in {1,2,4,8}).

    Little-endian within the byte: symbol j of byte holds bits
    [j*s, (j+1)*s).  Output dtype uint8, each value < 2^s.
    """
    b = jnp.asarray(b, jnp.uint8)
    if s == 8:
        return b
    if s not in (1, 2, 4):
        raise ValueError("byte-aligned symbol sizes are 1, 2, 4, 8")
    per = 8 // s
    shifts = jnp.arange(per, dtype=jnp.uint8) * s          # (per,)
    mask = jnp.uint8((1 << s) - 1)
    sym = (b[:, None] >> shifts[None, :]) & mask           # (n, per)
    return sym.reshape(-1)


def symbols_to_bytes(sym: jnp.ndarray, s: int) -> jnp.ndarray:
    """Inverse of :func:`bytes_to_symbols`."""
    sym = jnp.asarray(sym, jnp.uint8)
    if s == 8:
        return sym
    per = 8 // s
    sym = sym.reshape(-1, per)
    shifts = jnp.arange(per, dtype=jnp.uint8) * s
    return jax.lax.reduce(
        (sym << shifts[None, :]).astype(jnp.uint8),
        np.uint8(0), jax.lax.bitwise_or, (1,),
    )


# ---------------------------------------------------------------------------
# pytree <-> packet
# ---------------------------------------------------------------------------

def _leaf_to_bytes(x: jnp.ndarray) -> jnp.ndarray:
    x = jnp.asarray(x)
    if x.dtype == jnp.uint8:
        return x.reshape(-1)
    flat = x.reshape(-1)
    as_bytes = jax.lax.bitcast_convert_type(flat, jnp.uint8)
    return as_bytes.reshape(-1)


def _bytes_to_leaf(b: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint8:
        return b.reshape(shape)
    itemsize = dtype.itemsize
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    grouped = b.reshape(n, itemsize)
    flat = jax.lax.bitcast_convert_type(grouped, dtype)
    return flat.reshape(shape)


def pytree_to_packet(tree, s: int = 8) -> tuple[jnp.ndarray, PacketSpec]:
    """Flatten a pytree into one GF(2^s) symbol packet (bit-exact)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    byte_chunks = [_leaf_to_bytes(l) for l in leaves]
    b = (jnp.concatenate(byte_chunks) if byte_chunks
         else jnp.zeros((0,), jnp.uint8))
    spec = PacketSpec(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves),
        dtypes=tuple(jnp.asarray(l).dtype for l in leaves),
        s=s,
        n_bytes=int(b.shape[0]),
    )
    return bytes_to_symbols(b, s), spec


def packet_to_pytree(packet: jnp.ndarray, spec: PacketSpec):
    """Reassemble the pytree from a symbol packet (bit-exact inverse)."""
    b = symbols_to_bytes(packet, spec.s)[: spec.n_bytes]
    leaves = []
    off = 0
    for shape, dtype in zip(spec.shapes, spec.dtypes, strict=True):
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = n * jnp.dtype(dtype).itemsize
        leaves.append(_bytes_to_leaf(b[off: off + nbytes], shape, dtype))
        off += nbytes
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# stacking clients
# ---------------------------------------------------------------------------

def stack_packets(packets: list[jnp.ndarray]) -> jnp.ndarray:
    """K same-length packets -> P matrix (K, L) for RLNC (paper eq. P)."""
    L = packets[0].shape[0]
    for p in packets:
        if p.shape != (L,):
            raise ValueError("all client packets must have equal length")
    return jnp.stack(packets, axis=0)


# ---------------------------------------------------------------------------
# batched packetization (vmap over clients — the engine hot path)
# ---------------------------------------------------------------------------

def pytrees_to_packets(trees: list, s: int = 8
                       ) -> tuple[jnp.ndarray, PacketSpec]:
    """K same-structure pytrees -> (K, L) symbol matrix in one shot.

    Equivalent to ``stack_packets([pytree_to_packet(t, s)[0] ...])``
    but the byte-flatten and symbol-split run once under `vmap` over
    the stacked client axis instead of K separate Python-loop traces.
    """
    if not trees:
        raise ValueError("need at least one client pytree")
    leaves0, treedef = jax.tree_util.tree_flatten(trees[0])
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)
    sleaves = jax.tree_util.tree_flatten(stacked)[0]
    chunks = [jax.vmap(_leaf_to_bytes)(l) for l in sleaves]
    K = len(trees)
    b = (jnp.concatenate(chunks, axis=1) if chunks
         else jnp.zeros((K, 0), jnp.uint8))
    spec = PacketSpec(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves0),
        dtypes=tuple(jnp.asarray(l).dtype for l in leaves0),
        s=s,
        n_bytes=int(b.shape[1]),
    )
    sym = jax.vmap(lambda row: bytes_to_symbols(row, s))(b)
    return sym, spec


def packets_to_pytrees(P_hat: jnp.ndarray, spec: PacketSpec):
    """(K, L) decoded symbols -> ONE stacked pytree (leading K axis).

    Batched inverse of :func:`pytrees_to_packets`; index the leading
    axis (or tree_map over it) to recover per-client trees.
    """
    b = jax.vmap(lambda row: symbols_to_bytes(row, spec.s))(P_hat)
    b = b[:, : spec.n_bytes]
    leaves = []
    off = 0
    for shape, dtype in zip(spec.shapes, spec.dtypes, strict=True):
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = n * jnp.dtype(dtype).itemsize
        leaves.append(jax.vmap(
            lambda bb, sh=shape, dt=dtype: _bytes_to_leaf(bb, sh, dt)
        )(b[:, off: off + nbytes]))
        off += nbytes
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# wire formats: materialized rows vs seed-addressed packets
# ---------------------------------------------------------------------------
#
# An encoded tuple on the wire is header + payload.  The materialized
# format ships the K-symbol coding row (K·s/8 bytes); the seeded
# format (repro.core.seeds) ships a 4-byte uint32 seed from which the
# receiver regenerates the row — the paper's overhead objection at
# large K drops from K+L to 4+L bytes per packet.

SEED_WIRE_BYTES = 4


def coding_row_wire_bytes(K: int, s: int) -> int:
    """Bytes a materialized K-symbol GF(2^s) coding row occupies."""
    return -(-K * s // 8)


def packet_wire_bytes(K: int, payload_symbols: int, s: int,
                      *, seeded: bool) -> int:
    """Total wire bytes of one encoded tuple (header + payload).

    >>> packet_wire_bytes(128, 4096, 8, seeded=False)   # K + L
    4224
    >>> packet_wire_bytes(128, 4096, 8, seeded=True)    # 4 + L
    4100
    """
    header = SEED_WIRE_BYTES if seeded else coding_row_wire_bytes(K, s)
    return header + -(-payload_symbols * s // 8)


def pack_seed_packet(seed, payload: jnp.ndarray, s: int) -> jnp.ndarray:
    """Serialize one seeded tuple: 4 seed bytes (LE) + payload bytes."""
    seed_bytes = jax.lax.bitcast_convert_type(
        jnp.asarray(seed, jnp.uint32).reshape(1), jnp.uint8).reshape(-1)
    return jnp.concatenate(
        [seed_bytes, symbols_to_bytes(payload, s)])


def unpack_seed_packet(buf: jnp.ndarray, s: int
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of :func:`pack_seed_packet`: (seed uint32, payload)."""
    buf = jnp.asarray(buf, jnp.uint8)
    seed = jax.lax.bitcast_convert_type(
        buf[:SEED_WIRE_BYTES].reshape(1, SEED_WIRE_BYTES),
        jnp.uint32).reshape(())
    return seed, bytes_to_symbols(buf[SEED_WIRE_BYTES:], s)


# ---------------------------------------------------------------------------
# quantized variant (paper ref [22]: pruning-quantization coding design)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantSpec:
    scales: tuple[float, ...]
    zeros: tuple[float, ...]


def quantize_pytree(tree, bits: int = 8):
    """Per-tensor affine quantization to uint8 in [0, 2^bits)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qleaves, scales, zeros = [], [], []
    qmax = float(2**bits - 1)
    for l in leaves:
        l = jnp.asarray(l, jnp.float32)
        lo = jnp.min(l)
        hi = jnp.max(l)
        scale = jnp.maximum((hi - lo) / qmax, 1e-12)
        q = jnp.clip(jnp.round((l - lo) / scale), 0, qmax).astype(jnp.uint8)
        qleaves.append(q)
        scales.append(float(scale))
        zeros.append(float(lo))
    qtree = jax.tree_util.tree_unflatten(treedef, qleaves)
    return qtree, QuantSpec(tuple(scales), tuple(zeros))


def dequantize_pytree(qtree, qspec: QuantSpec):
    leaves, treedef = jax.tree_util.tree_flatten(qtree)
    out = [
        jnp.asarray(q, jnp.float32) * s + z
        for q, s, z in zip(leaves, qspec.scales, qspec.zeros,
                           strict=True)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
