"""Seed-derived RLNC coding vectors: counter-based PRNG + row expansion.

FedNC's per-packet overhead objection at large generation size K is the
coding vector itself: every tuple ships a K-symbol GF(2^s) row next to
its L-symbol payload.  This module replaces the shipped row with a
**4-byte seed**: coefficient j of a row is a pure function of
``(seed, j)`` through a counter-based PRNG, so any party holding the
seed regenerates the row on demand — on the wire a packet is 4+L bytes
instead of K+L, and the seeded GF kernels (``repro.kernels``,
``repro.engine.registry``) rebuild their coefficient tile *inside* the
matmul, so the (N, K) matrix never hits HBM on the encode path.

The PRNG is **Threefry-2x32 (20 rounds)**, implemented here with plain
uint32 adds/rotates/XORs so the *identical* bitstream is computable

* in pure jnp on CPU (``jnp_seeded`` / ``jnp_packed_seeded``),
* inside a Pallas TPU kernel body (``pallas_packed_seeded``) — unlike
  the hardware ``pltpu.prng_random_bits``, which is not reproducible
  across backends, and
* by any receiver that wants to materialize the row (decode, tests).

Bit-exactness is the whole contract: same seed ⇒ byte-identical row
everywhere, property-tested against the Random123 known-answer vectors
and the materialized kernels in tests/test_seeded.py.

Layout: coefficient j of a row comes from byte ``j % 4`` of the
Threefry output word with counter ``j // 4`` (key = ``(seed, SALT)``),
masked to s bits — 4 coefficients per generated word, uniform over
[0, 2^s) because Threefry words are uniform over uint32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

SEED_DTYPE = jnp.uint32
SEED_WIRE_BYTES = 4          # one uint32 seed replaces the K-symbol row
COEFFS_PER_WORD = 4          # one coefficient byte per Threefry-word byte

# Domain-separation constant ("FdNC"): the second Threefry key word.
# Fixed forever — changing it silently changes every derived row.
KEY_SALT = np.uint32(0x46644E43)

_THREEFRY_C240 = np.uint32(0x1BD11BDA)
_ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
_ROUNDS = 20


def _rotl32(x, r: int):
    """Rotate-left on uint32 lanes (r static, 0 < r < 32)."""
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32-20 block cipher: key (k0, k1), counter (x0, x1).

    All inputs broadcastable uint32 arrays; returns the two output
    words.  Matches the Random123 reference (and jax.random's core)
    bit for bit — verified against the published known-answer vectors
    in tests/test_seeded.py.  Pure adds/rotates/XORs on uint32, so the
    same function body runs in jnp *and* inside a Pallas kernel.
    """
    k0 = jnp.asarray(k0, SEED_DTYPE)
    k1 = jnp.asarray(k1, SEED_DTYPE)
    x0 = jnp.asarray(x0, SEED_DTYPE)
    x1 = jnp.asarray(x1, SEED_DTYPE)
    ks = (k0, k1, _THREEFRY_C240 ^ k0 ^ k1)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for d in range(_ROUNDS):                      # static unroll
        x0 = x0 + x1
        x1 = _rotl32(x1, _ROTATIONS[d % 8])
        x1 = x1 ^ x0
        if d % 4 == 3:
            j = d // 4 + 1                        # key-injection index
            x0 = x0 + ks[j % 3]
            x1 = x1 + ks[(j + 1) % 3] + np.uint32(j)
    return x0, x1


def coeff_words(seeds, n_words: int):
    """(N,) uint32 seeds -> (N, n_words) uint32 coefficient words.

    Word w of row i is ``threefry2x32(seed_i, SALT; w, 0)[0]`` — a
    counter-based stream, so any sub-range of words is computable
    without generating its predecessors.  Uses a 2-D broadcasted iota
    for the counter (TPU vector units have no 1-D iota).
    """
    seeds = jnp.asarray(seeds, SEED_DTYPE)
    n = seeds.shape[0]
    ctr = jax.lax.broadcasted_iota(SEED_DTYPE, (n, n_words), 1)
    w0, _ = threefry2x32(seeds[:, None], KEY_SALT, ctr,
                         jnp.zeros_like(ctr))
    return w0


def expand_rows(seeds, K: int, s: int = 8) -> jnp.ndarray:
    """Regenerate the (N, K) uint8 coding matrix from (N,) uint32 seeds.

    Coefficient j = byte ``j % 4`` of word ``j // 4``, masked to s
    bits — uniform over [0, 2^s).  This is *the* definition of a
    seed-addressed row; every seeded kernel and the wire format agree
    with it byte for byte.

    >>> import jax.numpy as jnp
    >>> A = expand_rows(jnp.array([7, 7, 9], dtype=jnp.uint32), K=5)
    >>> A.shape, A.dtype
    ((3, 5), dtype('uint8'))
    >>> bool((A[0] == A[1]).all())        # same seed, same row
    True
    >>> bool((A[0] == A[2]).all())        # different seed
    False
    """
    seeds = jnp.asarray(seeds, SEED_DTYPE)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be (N,), got {seeds.shape}")
    n_words = -(-K // COEFFS_PER_WORD)
    W = coeff_words(seeds, n_words)                   # (N, n_words)
    shifts = (jnp.arange(COEFFS_PER_WORD, dtype=SEED_DTYPE)
              * np.uint32(8))
    b = (W[:, :, None] >> shifts[None, None, :]) & np.uint32(0xFF)
    flat = b.reshape(seeds.shape[0], n_words * COEFFS_PER_WORD)
    mask = np.uint8((1 << s) - 1)
    return flat[:, :K].astype(jnp.uint8) & mask


@functools.partial(jax.jit, static_argnames=("K", "s"))
def _expand_rows_jit(seeds, *, K: int, s: int):
    return expand_rows(seeds, K, s)


def expand_rows_jit(seeds, K: int, s: int = 8) -> jnp.ndarray:
    """Jitted :func:`expand_rows` (host-side callers; kernels inline)."""
    return _expand_rows_jit(jnp.asarray(seeds, SEED_DTYPE), K=K, s=s)


def draw_seeds(key, n: int) -> jnp.ndarray:
    """Draw n uniform uint32 row seeds from a jax PRNG key.

    The seeded analogue of ``rlnc.random_coding_matrix`` — rows of
    ``expand_rows(draw_seeds(key, n), K, s)`` are uniform over
    GF(2^s)^K (up to the 2^32-seed family size; at FedNC scales the
    collision probability is the birthday bound n^2/2^33).
    """
    return jax.random.bits(key, (n,), SEED_DTYPE)
