"""Random Linear Network Coding over GF(2^s) (paper §II-B, Alg. 1).

Encoded tuples are ``(a_i, C_i)``: the coding vector and the coded
packet.  The server stacks K tuples into (A, C) and decodes with
Gaussian elimination when A is invertible; otherwise the FL round is
skipped (Alg. 1, else-branch).

`recode` implements the network-interior operation that Prop. 2's η
counts: a relay holding tuples (A, C) emits fresh random combinations
(R·A, R·C) without ever decoding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .gf import GF, ge_solve, get_field, rank as gf_rank


@dataclass(frozen=True)
class EncodedBatch:
    """K encoded tuples: A (n, K) coding matrix, C (n, L) coded packets."""

    A: jnp.ndarray
    C: jnp.ndarray

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def K(self) -> int:
        return self.A.shape[1]

    def __getitem__(self, idx) -> "EncodedBatch":
        return EncodedBatch(A=self.A[idx], C=self.C[idx])

    def concat(self, other: "EncodedBatch") -> "EncodedBatch":
        return EncodedBatch(
            A=jnp.concatenate([self.A, other.A], 0),
            C=jnp.concatenate([self.C, other.C], 0),
        )


@dataclass(frozen=True)
class SeededBatch:
    """n seed-addressed encoded tuples: 4-byte seeds instead of rows.

    The wire analogue of :class:`EncodedBatch` for the seeded kernel
    family (`repro.core.seeds`): each tuple carries a uint32 seed from
    which any party regenerates its K-symbol coding row — 4+L bytes
    per packet instead of K+L.  ``K`` is carried explicitly because it
    is no longer readable off the (absent) coding matrix.
    """

    seeds: jnp.ndarray            # (n,) uint32 row seeds
    C: jnp.ndarray                # (n, L) uint8 coded payloads
    K: int                        # generation size (columns of A)

    @property
    def n(self) -> int:
        return self.seeds.shape[0]

    def __getitem__(self, idx) -> "SeededBatch":
        return SeededBatch(seeds=self.seeds[idx], C=self.C[idx],
                           K=self.K)

    def concat(self, other: "SeededBatch") -> "SeededBatch":
        if other.K != self.K:
            raise ValueError("generation sizes differ")
        return SeededBatch(
            seeds=jnp.concatenate([self.seeds, other.seeds], 0),
            C=jnp.concatenate([self.C, other.C], 0), K=self.K)

    def expand(self, s: int) -> EncodedBatch:
        """Materialize the coding matrix: the bit-exactness bridge.

        ``expand(s).A == seeds.expand_rows(seeds, K, s)`` by
        construction, so every seeded code path can be checked against
        the materialized pipeline byte for byte.
        """
        from .seeds import expand_rows_jit
        return EncodedBatch(A=expand_rows_jit(self.seeds, self.K, s),
                            C=self.C)


def random_coding_matrix(key, n: int, K: int, s: int) -> jnp.ndarray:
    """n random coding vectors over GF(2^s) — uniform incl. zero (RLNC)."""
    return get_field(s).random_elements(key, (n, K))


def random_coding_seeds(key, n: int) -> jnp.ndarray:
    """n uint32 row seeds — the seed-addressed RLNC draw.

    Rows of ``seeds.expand_rows(random_coding_seeds(key, n), K, s)``
    are uniform over GF(2^s)^K, the seeded analogue of
    :func:`random_coding_matrix`."""
    from .seeds import draw_seeds
    return draw_seeds(key, n)


def encode_seeded(P: jnp.ndarray, seeds: jnp.ndarray, s: int,
                  *, impl: str = "auto_seeded") -> SeededBatch:
    """C = rows(seeds)·P without materializing the coding matrix.

    `impl` must name a seeded registry kernel ('auto_seeded',
    'jnp_seeded', 'jnp_packed_seeded', 'pallas_packed_seeded').  The
    returned batch decodes identically to
    ``encode(P, expand_rows(seeds, K, s), s)``.
    """
    from repro.engine.registry import gf_matmul  # late import, avoids cycle
    seeds = jnp.asarray(seeds, jnp.uint32)
    C = gf_matmul(seeds, P, s=s, kernel=impl)
    return SeededBatch(seeds=seeds, C=C, K=int(P.shape[0]))


def encode(P: jnp.ndarray, A: jnp.ndarray, s: int,
           *, impl: str = "auto") -> EncodedBatch:
    """C = A·P over GF(2^s).  P: (K, L) symbols, A: (n, K) coefficients.

    impl is a kernel-registry name (repro.engine.registry): 'auto',
    'jnp', 'pallas', 'jnp_packed', ... — 'auto' resolves to the
    lane-packed kernel for the current backend.
    """
    from repro.engine.registry import gf_matmul  # late import, avoids cycle
    C = gf_matmul(A, P, s=s, kernel=impl)
    return EncodedBatch(A=jnp.asarray(A, jnp.uint8), C=C)


def sparse_coding_matrix(key, n: int, K: int, s: int,
                         density: float = 0.5) -> jnp.ndarray:
    """Sparse RLNC: each coefficient is zero w.p. (1-density), nonzero
    uniform otherwise, with at least one nonzero per row.  Encode cost
    scales with density; decode-failure probability rises as density
    falls (standard sparse-NC trade-off — benchmarked, not assumed)."""
    field = get_field(s)
    k1, k2, k3 = jax.random.split(key, 3)
    vals = field.random_nonzero(k1, (n, K))
    keep = jax.random.bernoulli(k2, density, (n, K))
    # guarantee one nonzero per row (place at a random column)
    col = jax.random.randint(k3, (n,), 0, K)
    keep = keep.at[jnp.arange(n), col].set(True)
    return jnp.where(keep, vals, jnp.uint8(0))


def systematic_coding_matrix(key, n: int, K: int, s: int) -> jnp.ndarray:
    """First K rows identity (original packets), remaining rows random.

    Systematic RLNC: receivers that get the plain rows decode for free;
    coded rows repair erasures.  (Beyond-paper convenience, standard in
    the NC literature the paper builds on.)
    """
    field = get_field(s)
    eye = jnp.eye(K, dtype=jnp.uint8)
    if n <= K:
        return eye[:n]
    extra = field.random_elements(key, (n - K, K))
    return jnp.concatenate([eye, extra], axis=0)


def recode(batch: EncodedBatch, key, n_out: int, s: int,
           *, impl: str = "auto") -> EncodedBatch:
    """Relay recoding: emit n_out fresh random combinations of the
    received tuples.  New coding vectors compose linearly: A' = R·A.

    Thin adapter over :meth:`repro.engine.CodingEngine.recode` — the
    mixing products run chunk-streamed through the registry kernel
    named by `impl` (same names as :func:`encode`), bit-identical to
    the historical host-side field.matmul."""
    from repro.engine import EngineConfig, get_engine  # late: avoids cycle
    return get_engine(EngineConfig(s=s, kernel=impl)).recode(batch, key,
                                                             n_out)


def decodable(batch: EncodedBatch, s: int) -> jnp.ndarray:
    """True iff the received coding matrix has full column rank K."""
    return gf_rank(get_field(s), batch.A) == batch.K


def decode(batch: EncodedBatch, s: int):
    """(ok, P_hat): Gaussian-elimination decode of K tuples (Alg. 1).

    Requires n == K; for n > K callers first select K rows (e.g. via
    `select_decodable_rows`) — matching the paper's server that waits
    for exactly K tuples.
    """
    if batch.n != batch.K:
        raise ValueError(
            f"decode needs square A; got {batch.n} tuples for K={batch.K}"
        )
    field = get_field(s)
    return ge_solve(field, batch.A, batch.C)


def select_rows(batch: EncodedBatch, s: int
                ) -> tuple[jnp.ndarray, EncodedBatch]:
    """(ok, K-row batch): greedily pick K linearly-independent tuples
    out of n >= K with the jit-safe incremental-GE pass
    (repro.engine.select) — fully on-device, no host numpy."""
    from repro.engine.select import incremental_select
    ok, idx, _ = incremental_select(batch.A, s)
    return ok, EncodedBatch(A=batch.A[idx], C=batch.C[idx])


def select_decodable_rows(batch: EncodedBatch, s: int) -> EncodedBatch:
    """Greedy K-independent-row selection (legacy signature).

    Same selection as the historical host-side numpy loop — greedy in
    row order — but computed on-device; prefer :func:`select_rows`,
    which also reports whether full rank was reached."""
    return select_rows(batch, s)[1]


# ---------------------------------------------------------------------------
# float-field RLNC (mesh/in-datacenter variant, DESIGN.md §3b)
# ---------------------------------------------------------------------------

def float_coding_matrix(key, n: int, K: int) -> jnp.ndarray:
    """Random real coefficients (Gaussian): invertible almost surely."""
    return jax.random.normal(key, (n, K), jnp.float32)


def float_encode(P: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """C = A @ P over the reals. P: (K, L) float updates."""
    return A.astype(P.dtype) @ P


def float_decode(A: jnp.ndarray, C: jnp.ndarray):
    """(ok, P_hat) via linear solve; ok = well-conditioned."""
    P_hat = jnp.linalg.solve(A.astype(jnp.float32), C.astype(jnp.float32))
    cond_ok = jnp.all(jnp.isfinite(P_hat))
    return cond_ok, P_hat.astype(C.dtype)
