"""Proposition 1: the coupon-collector ('blind box') analysis.

FedAvg under blind-box reception needs E[G] = K·H(K) ≈ K ln K + γK
random draws to hear from all K clients; FedNC needs ~K draws (any K
linearly-independent coded packets decode).  This module provides the
exact math, the asymptotic expansion the paper quotes (eq. 5), and
Monte-Carlo simulations of both collection processes.
"""
from __future__ import annotations

import math

import numpy as np

EULER_GAMMA = 0.5772156649015329


def harmonic(K: int) -> float:
    """H(K) = 1 + 1/2 + ... + 1/K (exact)."""
    return float(sum(1.0 / i for i in range(1, K + 1)))


def expected_draws_fedavg(K: int) -> float:
    """Exact E[G] = K·H(K) (paper eq. 7)."""
    return K * harmonic(K)


def expected_draws_fedavg_asymptotic(K: int) -> float:
    """Paper eq. 5: K ln K + γK + 1/2 + O(1/K)."""
    return K * math.log(K) + EULER_GAMMA * K + 0.5


def expected_draws_fednc(K: int, s: int = 8) -> float:
    """E[#coded packets to reach rank K] with uniform RLNC coefficients.

    Collecting rank i -> i+1 succeeds per draw with probability
    1 - q^(i-K) (a uniform vector avoids an i-dim subspace of F_q^K),
    so  E = Σ_{i=0}^{K-1} 1 / (1 - q^{i-K}).  For q=256 this is
    K + 1/255 + ... ≈ K — the paper's O(K) claim, made exact.
    """
    q = float(2**s)
    return float(sum(1.0 / (1.0 - q ** (i - K)) for i in range(K)))


def simulate_fedavg_draws(K: int, trials: int, seed: int = 0) -> np.ndarray:
    """Monte-Carlo G for the FedAvg blind-box collector."""
    rng = np.random.default_rng(seed)
    out = np.empty(trials, dtype=np.int64)
    for t in range(trials):
        seen: set[int] = set()
        g = 0
        while len(seen) < K:
            seen.add(int(rng.integers(0, K)))
            g += 1
        out[t] = g
    return out


def simulate_fednc_draws(K: int, s: int, trials: int, seed: int = 0
                         ) -> np.ndarray:
    """Monte-Carlo #draws for FedNC: draw uniform coding vectors over
    GF(2^s)^K until the stack reaches rank K (GF rank via repro.core.gf)."""
    import jax
    import jax.numpy as jnp

    from .gf import get_field, rank as gf_rank

    field = get_field(s)
    rng = np.random.default_rng(seed)
    out = np.empty(trials, dtype=np.int64)
    for t in range(trials):
        rows: list[np.ndarray] = []
        r = 0
        g = 0
        while r < K:
            key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
            rows.append(np.asarray(field.random_elements(key, (K,))))
            g += 1
            r = int(gf_rank(field, jnp.asarray(np.stack(rows))))
        out[t] = g
    return out
