"""Proposition 1: the coupon-collector ('blind box') analysis.

FedAvg under blind-box reception needs E[G] = K·H(K) ≈ K ln K + γK
random draws to hear from all K clients; FedNC needs ~K draws (any K
linearly-independent coded packets decode).  This module provides the
exact math, the asymptotic expansion the paper quotes (eq. 5), and
Monte-Carlo simulations of both collection processes.
"""
from __future__ import annotations

import math

import numpy as np

EULER_GAMMA = 0.5772156649015329


def harmonic(K: int) -> float:
    """H(K) = 1 + 1/2 + ... + 1/K (exact)."""
    return float(sum(1.0 / i for i in range(1, K + 1)))


def expected_draws_fedavg(K: int) -> float:
    """Exact E[G] = K·H(K) (paper eq. 7)."""
    return K * harmonic(K)


def expected_draws_fedavg_asymptotic(K: int) -> float:
    """Paper eq. 5: K ln K + γK + 1/2 + O(1/K)."""
    return K * math.log(K) + EULER_GAMMA * K + 0.5


def expected_draws_fednc(K: int, s: int = 8) -> float:
    """E[#coded packets to reach rank K] with uniform RLNC coefficients.

    Collecting rank i -> i+1 succeeds per draw with probability
    1 - q^(i-K) (a uniform vector avoids an i-dim subspace of F_q^K),
    so  E = Σ_{i=0}^{K-1} 1 / (1 - q^{i-K}).  For q=256 this is
    K + 1/255 + ... ≈ K — the paper's O(K) claim, made exact.
    """
    q = float(2**s)
    return float(sum(1.0 / (1.0 - q ** (i - K)) for i in range(K)))


def simulate_fedavg_draws(K: int, trials: int, seed: int = 0) -> np.ndarray:
    """Monte-Carlo G for the FedAvg blind-box collector, batched.

    Uses the geometric-stage decomposition: with i coupons held, the
    next new one takes Geom((K-i)/K) draws, and the stages are
    independent — so G = Σ_i Geom((K-i)/K) has *exactly* the law of
    the draw-by-draw collector.  One (trials, K) geometric sample
    replaces the per-trial Python loop of the seed.
    """
    rng = np.random.default_rng(seed)
    p = (K - np.arange(K, dtype=np.float64)) / K
    draws = rng.geometric(np.broadcast_to(p, (trials, K)))
    return draws.sum(axis=1).astype(np.int64)


def simulate_fednc_draws(K: int, s: int, trials: int, seed: int = 0
                         ) -> np.ndarray:
    """Monte-Carlo #draws for FedNC: uniform coding vectors over
    GF(2^s)^K until the stack reaches rank K.

    Batched: all trials draw their candidate stacks up front and a
    vmapped `engine.select.incremental_select` (real GF elimination,
    not the closed-form stage law — this is the measurement the
    formula is checked against) finds, per trial, the scan position of
    the K-th independent row; +1 is the draw count.  Trials whose
    stack ran out of rows before rank K (probability ~q^-margin)
    retry with a doubled stack.
    """
    import jax
    import jax.numpy as jnp

    from repro.engine.select import incremental_select

    rng = np.random.default_rng(seed)
    q = 1 << s
    out = np.zeros(trials, dtype=np.int64)
    todo = np.arange(trials)
    n_max = 2 * K + 8
    select = jax.vmap(lambda A: incremental_select(A, s))
    while todo.size:
        stacks = rng.integers(0, q, size=(todo.size, n_max, K),
                              dtype=np.uint8)
        ok, sel, _ = select(jnp.asarray(stacks))
        ok = np.asarray(ok)
        # sel is in scan order: position K-1 holds the index of the
        # K-th independent row — the draw on which rank hit K
        out[todo] = np.asarray(sel)[:, K - 1] + 1
        todo = todo[~ok]
        n_max *= 2
    return out
