"""Security & error-probability analysis (paper §III-A.1, Prop. 2).

* `error_probability_bound(s, eta)` — the paper's eq. (10):
      p_e <= 1 - (1 - 2^-s)^η
  the FedNC decode-failure bound with one receiver (d=1).
* `simulate_error_probability` — Monte-Carlo decode-failure rate of a
  FedNC round pushed through a MultiHopChannel; validates Table I's
  'Error Probability' column (0.5 / 0.0625 / 0.0039 / 0.3239).
* `full_rank_probability(n, K, s)` — exact P[an n×K uniform GF(2^s)
  matrix has rank K]; 0 whenever n < K (the rank-K wall every
  adversary hits).
* `eavesdropper_leak_probability(n, K, p, s)` — closed-form
  probability that an attacker intercepting each of n transmitted
  coded tuples independently with probability p achieves rank K: the
  binomial mixture of `full_rank_probability` over the intercepted
  count.  Monte-Carlo-validated by ``benchmarks/bench_security.py``
  through :class:`repro.adversary.EavesdropperView`.
* `eavesdropper_full_leak_probability(K, p, s)` — the n == K special
  case: all K tuples must be captured AND the K×K matrix must be
  nonsingular, i.e. p^K · Π(1 - q^-i).
"""
from __future__ import annotations

import math

import numpy as np


def error_probability_bound(s: int, eta: int) -> float:
    """Paper eq. (10): p_e <= 1 - (1 - 2^-s)^η."""
    return 1.0 - (1.0 - 2.0 ** (-s)) ** eta


def singular_probability_uniform(K: int, s: int) -> float:
    """Exact P[K×K uniform GF(2^s) matrix is singular]:
    1 - Π_{i=1..K} (1 - q^-i),  q = 2^s."""
    q = float(2**s)
    p_ns = 1.0
    for i in range(1, K + 1):
        p_ns *= 1.0 - q ** (-i)
    return 1.0 - p_ns


def full_rank_probability(n: int, K: int, s: int) -> float:
    """Exact P[an n×K uniform GF(2^s) matrix has rank K] (n rows heard,
    K sources): Π_{i=0}^{K-1} (1 - q^-(n-i)), and 0 for n < K — fewer
    than K intercepted tuples can never reach rank K, whatever the
    coefficients."""
    if n < K:
        return 0.0
    q = float(2**s)
    p = 1.0
    for i in range(K):
        p *= 1.0 - q ** (-(n - i))
    return p


def simulate_error_probability(K: int, s: int, eta: int, trials: int,
                               seed: int = 0) -> float:
    """Monte-Carlo decode-failure rate through η re-coding hops."""
    import jax
    import jax.numpy as jnp

    from .channel import MultiHopChannel
    from .rlnc import EncodedBatch, random_coding_matrix

    rng = np.random.default_rng(seed)
    failures = 0
    for _ in range(trials):
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        A = random_coding_matrix(key, K, K, s)
        # packets irrelevant for rank statistics; 1-symbol payload
        batch = EncodedBatch(A=A, C=jnp.zeros((K, 1), jnp.uint8))
        # Prop. 2's η counts EVERY link carrying independent random
        # coefficients — the source's own encode is one of them, so the
        # network applies η-1 further recoding hops.
        chan = MultiHopChannel(eta=max(eta - 1, 0),
                               seed=int(rng.integers(0, 2**31 - 1)))
        _, rep = chan.transmit_encoded(batch, s)
        failures += int(not rep.decodable)
    return failures / trials


def eavesdropper_leak_probability(n: int, K: int, p_intercept: float,
                                  s: int = 8) -> float:
    """P[attacker reaches rank K] when each of n transmitted coded
    tuples is intercepted independently with probability p.

    Binomial mixture over the intercepted count e (every subset of a
    uniform RLNC stack is itself uniform):

        Σ_e C(n, e) · p^e (1-p)^(n-e) · full_rank_probability(e, K, s)

    Terms with e < K vanish — the paper's security claim that an
    eavesdropper holding fewer than K tuples learns *nothing* about
    the K source packets."""
    p = float(p_intercept)
    total = 0.0
    for e in range(K, n + 1):
        total += (math.comb(n, e) * p**e * (1.0 - p) ** (n - e)
                  * full_rank_probability(e, K, s))
    return total


def eavesdropper_full_leak_probability(K: int, p_intercept: float,
                                       s: int = 8) -> float:
    """P[attacker reaches rank K] when exactly K coded tuples are
    transmitted, each intercepted independently with prob p.

    Needs all K tuples AND the K×K coding matrix nonsingular:
        p^K · Π_{i=1..K}(1 - q^-i)
    (== ``eavesdropper_leak_probability(K, K, p, s)``).
    Compare FedAvg: expected leaked client models = p·K > 0 for any p.
    """
    q = float(2**s)
    p_ns = 1.0
    for i in range(1, K + 1):
        p_ns *= 1.0 - q ** (-i)
    return (p_intercept ** K) * p_ns


def fedavg_expected_leak(K: int, p_intercept: float) -> float:
    """Expected number of client models leaked without coding."""
    return p_intercept * K
