"""Security & error-probability analysis (paper §III-A.1, Prop. 2).

* `error_probability_bound(s, eta)` — the paper's eq. (10):
      p_e <= 1 - (1 - 2^-s)^η
  the FedNC decode-failure bound with one receiver (d=1).
* `simulate_error_probability` — Monte-Carlo decode-failure rate of a
  FedNC round pushed through a MultiHopChannel; validates Table I's
  'Error Probability' column (0.5 / 0.0625 / 0.0039 / 0.3239).
* `eavesdropper_leak_probability` — closed-form probability that an
  attacker intercepting each of the K uploaded tuples independently
  with probability p achieves full rank (= must capture all K tuples
  if only K are ever sent, scaled by the rank statistics of RLNC).
"""
from __future__ import annotations

import numpy as np


def error_probability_bound(s: int, eta: int) -> float:
    """Paper eq. (10): p_e <= 1 - (1 - 2^-s)^η."""
    return 1.0 - (1.0 - 2.0 ** (-s)) ** eta


def singular_probability_uniform(K: int, s: int) -> float:
    """Exact P[K×K uniform GF(2^s) matrix is singular]:
    1 - Π_{i=1..K} (1 - q^-i),  q = 2^s."""
    q = float(2**s)
    p_ns = 1.0
    for i in range(1, K + 1):
        p_ns *= 1.0 - q ** (-i)
    return 1.0 - p_ns


def simulate_error_probability(K: int, s: int, eta: int, trials: int,
                               seed: int = 0) -> float:
    """Monte-Carlo decode-failure rate through η re-coding hops."""
    import jax
    import jax.numpy as jnp

    from .channel import MultiHopChannel
    from .rlnc import EncodedBatch, random_coding_matrix

    rng = np.random.default_rng(seed)
    failures = 0
    for _ in range(trials):
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        A = random_coding_matrix(key, K, K, s)
        # packets irrelevant for rank statistics; 1-symbol payload
        batch = EncodedBatch(A=A, C=jnp.zeros((K, 1), jnp.uint8))
        # Prop. 2's η counts EVERY link carrying independent random
        # coefficients — the source's own encode is one of them, so the
        # network applies η-1 further recoding hops.
        chan = MultiHopChannel(eta=max(eta - 1, 0),
                               seed=int(rng.integers(0, 2**31 - 1)))
        _, rep = chan.transmit_encoded(batch, s)
        failures += int(not rep.decodable)
    return failures / trials


def eavesdropper_full_leak_probability(K: int, p_intercept: float,
                                       s: int = 8) -> float:
    """P[attacker reaches rank K] when each of the K transmitted coded
    tuples is intercepted independently with prob p.

    Needs all K tuples AND the K×K coding matrix nonsingular:
        p^K · Π_{i=1..K}(1 - q^-i).
    Compare FedAvg: expected leaked client models = p·K > 0 for any p.
    """
    q = float(2**s)
    p_ns = 1.0
    for i in range(1, K + 1):
        p_ns *= 1.0 - q ** (-i)
    return (p_intercept ** K) * p_ns


def fedavg_expected_leak(K: int, p_intercept: float) -> float:
    """Expected number of client models leaked without coding."""
    return p_intercept * K
