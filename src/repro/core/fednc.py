"""FedNC round logic — Algorithm 1 of the paper, as a composable module.

One communication round:

    P   <- stack(packetize(w_k) for k in participants)     (paper: P)
    A   <- random coding matrix over GF(2^s)               (paper: a_i)
    C   <- A · P                                           (eq. 4)
    ... tuples (a_i, C_i) traverse the channel ...
    if A' (received) invertible:
        P_hat <- GE(A', C');  w <- Σ p_k · unpacketize(P_hat_k)
    else:
        w <- w_prev                                        (skip round)

The encode/decode field path is bit-exact (see core.packets), so when
decoding succeeds the aggregated model equals plain FedAvg on the same
client set — coding costs zero accuracy, exactly the paper's claim for
the iid/no-loss setting.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dfield
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import packets as pkt
from .channel import ChannelReport
from .gf import get_field
from .rlnc import EncodedBatch, decode, encode, random_coding_matrix


@dataclass(frozen=True)
class FedNCConfig:
    s: int = 8                 # field size (symbol bits), paper Table I
    kernel_impl: str = "auto"  # 'jnp' | 'pallas' | 'auto'
    extra_tuples: int = 0      # send K + extra coded tuples (erasure headroom)
    systematic: bool = False   # identity-prefixed coding matrix
    quantize_bits: int = 0     # 0 = bit-exact float bytes (default);
    #                            8 = paper-[22] affine int8 packets (4x
    #                            smaller uploads, lossy)
    coding_density: float = 1.0  # <1.0 = sparse RLNC coefficients


@dataclass
class RoundResult:
    global_params: Any
    decoded: bool
    report: Optional[ChannelReport]
    n_aggregated: int


def encode_clients(client_params: Sequence[Any], cfg: FedNCConfig, key
                   ) -> tuple[EncodedBatch, pkt.PacketSpec, Optional[list]]:
    """Packetize + RLNC-encode K client parameter pytrees.

    Returns (batch, spec, qspecs); qspecs is per-client quantization
    metadata when cfg.quantize_bits > 0 (it travels uncoded alongside
    the coding vectors — a few floats per tensor, like a_i itself)."""
    rows = []
    spec = None
    qspecs = None
    if cfg.quantize_bits:
        qspecs = []
        for p in client_params:
            q, qs = pkt.quantize_pytree(p, bits=cfg.quantize_bits)
            sym, spec = pkt.pytree_to_packet(q, s=cfg.s)
            rows.append(sym)
            qspecs.append(qs)
    else:
        for p in client_params:
            sym, spec = pkt.pytree_to_packet(p, s=cfg.s)
            rows.append(sym)
    P = pkt.stack_packets(rows)
    K = len(rows)
    n = K + cfg.extra_tuples
    if cfg.systematic:
        from .rlnc import systematic_coding_matrix
        A = systematic_coding_matrix(key, n, K, cfg.s)
    elif cfg.coding_density < 1.0:
        from .rlnc import sparse_coding_matrix
        A = sparse_coding_matrix(key, n, K, cfg.s,
                                 density=cfg.coding_density)
    else:
        A = random_coding_matrix(key, n, K, cfg.s)
    return encode(P, A, cfg.s, impl=cfg.kernel_impl), spec, qspecs


def decode_and_aggregate(batch: EncodedBatch, spec: pkt.PacketSpec,
                         weights: Sequence[float], prev_global: Any,
                         cfg: FedNCConfig,
                         qspecs: Optional[list] = None) -> RoundResult:
    """Server side of Alg. 1: GE decode, weighted FedAvg, or skip."""
    K = batch.K
    if batch.n < K:
        return RoundResult(prev_global, False, None, 0)
    if batch.n > K:
        from .rlnc import select_decodable_rows
        batch = select_decodable_rows(batch, cfg.s)
    ok, P_hat = decode(batch, cfg.s)
    if not bool(ok):
        return RoundResult(prev_global, False, None, 0)
    w = np.asarray(weights, np.float32)
    w = w / w.sum()
    decoded_trees = [pkt.packet_to_pytree(P_hat[k], spec) for k in range(K)]
    if qspecs is not None:
        decoded_trees = [pkt.dequantize_pytree(t, qs)
                         for t, qs in zip(decoded_trees, qspecs)]
    agg = jax.tree_util.tree_map(
        lambda *xs: sum(
            wk * jnp.asarray(x, jnp.float32) for wk, x in zip(w, xs)
        ).astype(xs[0].dtype),
        *decoded_trees,
    )
    return RoundResult(agg, True, None, K)


def fednc_round(client_params: Sequence[Any], weights: Sequence[float],
                prev_global: Any, cfg: FedNCConfig, key,
                channel=None) -> RoundResult:
    """Full Alg.-1 round with an optional channel between encode/decode."""
    batch, spec, qspecs = encode_clients(client_params, cfg, key)
    report = None
    if channel is not None:
        batch, report = channel.transmit_encoded(batch, cfg.s)
        if not report.decodable:
            return RoundResult(prev_global, False, report, 0)
    res = decode_and_aggregate(batch, spec, weights, prev_global, cfg,
                               qspecs=qspecs)
    res.report = report
    return res


def fedavg_round(client_params: Sequence[Any], weights: Sequence[float],
                 prev_global: Any, channel=None) -> RoundResult:
    """Classic FedAvg baseline (paper §II-A), same channel interface."""
    K = len(client_params)
    w = np.asarray(weights, np.float32)
    if channel is not None:
        stacked = jnp.stack(
            [pkt.pytree_to_packet(p, s=8)[0] for p in client_params])
        delivered, idx, report = channel.transmit_plain(stacked)
        if len(idx) == 0:
            return RoundResult(prev_global, False, report, 0)
        client_params = [client_params[i] for i in idx]
        w = w[list(idx)]
    else:
        report = None
    w = w / w.sum()
    agg = jax.tree_util.tree_map(
        lambda *xs: sum(
            wk * jnp.asarray(x, jnp.float32) for wk, x in zip(w, xs)
        ).astype(xs[0].dtype),
        *client_params,
    )
    return RoundResult(agg, True, report, len(client_params))
