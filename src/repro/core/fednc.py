"""FedNC round logic — Algorithm 1 of the paper, as a composable module.

One communication round:

    P   <- stack(packetize(w_k) for k in participants)     (paper: P)
    A   <- random coding matrix over GF(2^s)               (paper: a_i)
    C   <- A · P                                           (eq. 4)
    ... tuples (a_i, C_i) traverse the channel ...
    if A' (received) invertible:
        P_hat <- GE(A', C');  w <- Σ p_k · unpacketize(P_hat_k)
    else:
        w <- w_prev                                        (skip round)

The coded math — batched packetization, chunk-streamed kernel
execution, jit-safe row selection, decode — lives in
repro.engine.CodingEngine; this module is the thin Alg.-1 adapter that
maps FedNCConfig onto an engine and turns decoded packets back into a
weighted FedAvg aggregate.

The encode/decode field path is bit-exact (see core.packets), so when
decoding succeeds the aggregated model equals plain FedAvg on the same
client set — coding costs zero accuracy, exactly the paper's claim for
the iid/no-loss setting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.defaults import DEFAULT_CHUNK_L

from . import packets as pkt
from .channel import ChannelReport
from .rlnc import EncodedBatch


@dataclass(frozen=True)
class FedNCConfig:
    s: int = 8                 # field size (symbol bits), paper Table I
    kernel_impl: str = "auto"  # engine-registry kernel name
    extra_tuples: int = 0      # send K + extra coded tuples (erasure headroom)
    systematic: bool = False   # identity-prefixed coding matrix
    quantize_bits: int = 0     # 0 = bit-exact float bytes (default);
    #                            8 = paper-[22] affine int8 packets (4x
    #                            smaller uploads, lossy)
    coding_density: float = 1.0  # <1.0 = sparse RLNC coefficients
    chunk_l: int = DEFAULT_CHUNK_L  # streamed-chunk symbols (0 = one shot)


def engine_for(cfg: FedNCConfig) -> "repro.engine.CodingEngine":
    """The (cached) CodingEngine realizing this round configuration."""
    # call-time import: repro.engine eagerly imports repro.core, so this
    # adapter direction must stay lazy to keep both import orders legal
    from repro.engine import EngineConfig, get_engine
    return get_engine(EngineConfig(
        s=cfg.s,
        kernel=cfg.kernel_impl,
        chunk_l=cfg.chunk_l,
        extra_tuples=cfg.extra_tuples,
        systematic=cfg.systematic,
        coding_density=cfg.coding_density,
    ))


@dataclass
class RoundResult:
    global_params: Any
    decoded: bool
    report: Optional[ChannelReport]
    n_aggregated: int


def _packetize(client_params: Sequence[Any], cfg: FedNCConfig
               ) -> tuple[jnp.ndarray, pkt.PacketSpec, Optional[list]]:
    """(P, spec, qspecs): vmap-batched packetization of K clients.

    Quantization (the lossy paper-[22] variant) stays per-client — it
    produces a few Python floats of metadata each — but the byte/symbol
    packetization itself is always the single batched pass."""
    engine = engine_for(cfg)
    if cfg.quantize_bits:
        qspecs, qtrees = [], []
        for p in client_params:
            q, qs = pkt.quantize_pytree(p, bits=cfg.quantize_bits)
            qtrees.append(q)
            qspecs.append(qs)
        P, spec = engine.packetize(qtrees)
        return P, spec, qspecs
    P, spec = engine.packetize(client_params)
    return P, spec, None


def _aggregate(P_hat: jnp.ndarray, spec: pkt.PacketSpec,
               weights: Sequence[float], cfg: FedNCConfig,
               qspecs: Optional[list] = None) -> Any:
    """Decoded packets -> weighted FedAvg aggregate (paper §II-A)."""
    K = P_hat.shape[0]
    w = np.asarray(weights, np.float32)
    w = w / w.sum()
    stacked = pkt.packets_to_pytrees(P_hat, spec)
    if qspecs is not None:
        trees = [jax.tree_util.tree_map(lambda x, k=k: x[k], stacked)
                 for k in range(K)]
        trees = [pkt.dequantize_pytree(t, qs)
                 for t, qs in zip(trees, qspecs, strict=True)]
        return jax.tree_util.tree_map(
            lambda *xs: sum(
                wk * jnp.asarray(x, jnp.float32)
                for wk, x in zip(w, xs, strict=True)
            ).astype(xs[0].dtype),
            *trees,
        )
    # weighted sum over the stacked client axis, term order matching
    # fedavg_round's sequential sum so FedNC == FedAvg stays bit-exact
    return jax.tree_util.tree_map(
        lambda x: sum(
            wk * jnp.asarray(x[k], jnp.float32) for k, wk in enumerate(w)
        ).astype(x.dtype),
        stacked,
    )


def packetize_clients(client_params: Sequence[Any], cfg: FedNCConfig
                      ) -> tuple[jnp.ndarray, pkt.PacketSpec,
                                 Optional[list]]:
    """Public head of Alg. 1 for callers that run their own coded
    pipeline (e.g. the async strategy): honors `quantize_bits` and
    returns the qspecs the decode side needs."""
    return _packetize(client_params, cfg)


def aggregate_decoded(P_hat: jnp.ndarray, spec: pkt.PacketSpec,
                      weights: Sequence[float], cfg: FedNCConfig,
                      qspecs: Optional[list] = None) -> Any:
    """Public tail of Alg. 1 for callers that decode their own packets
    (e.g. the streaming rank-K decoder): decoded (K, L) symbols ->
    weighted FedAvg aggregate, identical math to `fednc_round`."""
    return _aggregate(P_hat, spec, weights, cfg, qspecs=qspecs)


def encode_clients(client_params: Sequence[Any], cfg: FedNCConfig, key
                   ) -> tuple[EncodedBatch, pkt.PacketSpec, Optional[list]]:
    """Packetize + RLNC-encode K client parameter pytrees.

    Returns (batch, spec, qspecs); qspecs is per-client quantization
    metadata when cfg.quantize_bits > 0 (it travels uncoded alongside
    the coding vectors — a few floats per tensor, like a_i itself)."""
    engine = engine_for(cfg)
    P, spec, qspecs = _packetize(client_params, cfg)
    K = P.shape[0]
    A = engine.coding_matrix(key, K + cfg.extra_tuples, K)
    return engine.encode(P, A), spec, qspecs


def decode_and_aggregate(batch: EncodedBatch, spec: pkt.PacketSpec,
                         weights: Sequence[float], prev_global: Any,
                         cfg: FedNCConfig,
                         qspecs: Optional[list] = None) -> RoundResult:
    """Server side of Alg. 1: decode (selecting K rows on-device when
    n > K), weighted FedAvg, or skip."""
    K = batch.K
    if batch.n < K:
        return RoundResult(prev_global, False, None, 0)
    ok, P_hat = engine_for(cfg).decode(batch)
    if not ok:
        return RoundResult(prev_global, False, None, 0)
    agg = _aggregate(P_hat, spec, weights, cfg, qspecs=qspecs)
    return RoundResult(agg, True, None, K)


def fednc_round(client_params: Sequence[Any], weights: Sequence[float],
                prev_global: Any, cfg: FedNCConfig, key,
                channel=None) -> RoundResult:
    """Full Alg.-1 round: a thin adapter over CodingEngine.round()."""
    engine = engine_for(cfg)
    P, spec, qspecs = _packetize(client_params, cfg)
    out = engine.round(P, key, channel=channel)
    if not out.ok:
        return RoundResult(prev_global, False, out.report, 0)
    agg = _aggregate(out.packets, spec, weights, cfg, qspecs=qspecs)
    return RoundResult(agg, True, out.report, P.shape[0])


def fedavg_round(client_params: Sequence[Any], weights: Sequence[float],
                 prev_global: Any, channel=None) -> RoundResult:
    """Classic FedAvg baseline (paper §II-A), same channel interface."""
    w = np.asarray(weights, np.float32)
    if channel is not None:
        stacked = pkt.pytrees_to_packets(client_params, s=8)[0]
        delivered, idx, report = channel.transmit_plain(stacked)
        if len(idx) == 0:
            return RoundResult(prev_global, False, report, 0)
        client_params = [client_params[i] for i in idx]
        w = w[list(idx)]
    else:
        report = None
    w = w / w.sum()
    agg = jax.tree_util.tree_map(
        lambda *xs: sum(
            wk * jnp.asarray(x, jnp.float32)
            for wk, x in zip(w, xs, strict=True)
        ).astype(xs[0].dtype),
        *client_params,
    )
    return RoundResult(agg, True, report, len(client_params))
