"""jit-safe incremental-GE row selection for the n > K erasure path.

The seed's `select_decodable_rows` was a host-side numpy greedy loop
that recomputed the rank of the picked prefix from scratch for every
candidate row — O(n·K) full eliminations, with a device->host sync per
row.  This module replaces it with a single forward elimination pass
that maintains pivot state on-device:

* ``B`` (K, K): the reduced basis — row c holds the (normalized) basis
  vector whose pivot sits in column c, zero if that pivot is unfilled.
  ``B`` is kept in *reduced* row-echelon form, so reducing a candidate
  row against the whole basis is one GF mat-vec.
* A candidate row is selected iff its reduction against the basis is
  nonzero (i.e. it is independent of everything selected so far) —
  exactly the greedy matroid rule of the old helper, so the selected
  index set is identical.

Everything is `lax.fori_loop` + `lax.cond`: no host numpy, no sync,
usable inside jit and under vmap over batches of coding matrices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.gf import get_field


def reduce_insert(field, B, Y, filled, a, c):
    """One candidate row (a, c) against the RREF basis [B | Y].

    The shared elimination step of `incremental_select` (Y zero-width)
    and `engine.stream.StreamDecoder` (Y = the payload block): reduce
    `a` in a single GF mat-vec (B is RREF, so subtracting a[p]·B[p]
    for every filled pivot p zeroes all filled pivot columns at once),
    and — when the residual is nonzero, i.e. the row is independent —
    normalize by the residual's first nonzero symbol and insert at
    that pivot, clearing its column from the existing rows to stay
    RREF.  Identical row operations hit Y, preserving the invariant
    B[p]·P = Y[p].  Returns ``(B, Y, filled, was_independent,
    inconsistent)``.

    ``inconsistent`` is the byzantine tripwire: an honest dependent
    arrival (a, c) = (Σ λ_p B[p], Σ λ_p Y[p]) reduces to zero in BOTH
    the coefficient and the payload column, so a zero coefficient
    residual with a NONZERO payload residual proves some tuple on this
    stream was corrupted (flipped symbols, a forged coding row, or a
    replayed seed with a different payload) — no honest channel, lossy
    or recoding, can produce it.
    """
    coeffs = jnp.where(filled, a, jnp.uint8(0))
    red_a = a ^ field.matmul(coeffs[None, :], B)[0]
    red_c = c ^ field.matmul(coeffs[None, :], Y)[0]
    nz = red_a != 0
    found = jnp.any(nz)
    bad = (~found) & jnp.any(red_c != 0)
    piv = jnp.argmax(nz)                    # first nonzero column

    def insert(args):
        B, Y, filled = args
        inv = field.inv(red_a[piv])
        new_a = field.mul(red_a, inv)
        new_c = field.mul(red_c, inv)
        fac = B[:, piv]
        B = (B ^ field.mul(fac[:, None], new_a[None, :])).at[piv].set(new_a)
        Y = (Y ^ field.mul(fac[:, None], new_c[None, :])).at[piv].set(new_c)
        return B, Y, filled.at[piv].set(True)

    B, Y, filled = jax.lax.cond(found, insert, lambda args: args,
                                (B, Y, filled))
    return B, Y, filled, found, bad


@functools.lru_cache(maxsize=None)
def _select_fn(s: int):
    field = get_field(s)

    @jax.jit
    def run(A: jnp.ndarray):
        A = jnp.asarray(A, jnp.uint8)
        n, K = A.shape
        c0 = jnp.zeros((0,), jnp.uint8)     # selection carries no payload

        def body(i, state):
            B, Y, filled, sel, count = state
            B, Y, filled, found, _ = reduce_insert(field, B, Y, filled,
                                                   A[i], c0)
            sel = jnp.where(found, sel.at[count].set(i), sel)
            return B, Y, filled, sel, count + found.astype(jnp.int32)

        state = (
            jnp.zeros((K, K), jnp.uint8),       # basis B
            jnp.zeros((K, 0), jnp.uint8),       # zero-width payload
            jnp.zeros((K,), jnp.bool_),         # filled pivots
            jnp.zeros((K,), jnp.int32),         # selected row indices
            jnp.int32(0),                       # selected count
        )
        _, _, _, sel, count = jax.lax.fori_loop(0, n, body, state)
        return count == K, sel, count

    return run


def incremental_select(A: jnp.ndarray, s: int):
    """Greedily pick K independent rows of A (n, K) over GF(2^s).

    Returns ``(ok, idx, count)``: `ok` — scalar bool, full column rank
    reached; `idx` — (K,) int32 selected row indices in scan order
    (positions >= count are 0-padded, matching the old helper); `count`
    — number of independent rows found (== rank of A, capped at K).

    Row 1 below is 2·row 0 over GF(2^8), so the selector skips it:

    >>> import jax.numpy as jnp
    >>> A = jnp.array([[1, 0], [2, 0], [0, 3]], dtype=jnp.uint8)
    >>> ok, idx, count = incremental_select(A, 8)
    >>> bool(ok), idx.tolist(), int(count)
    (True, [0, 2], 2)
    """
    return _select_fn(s)(A)
