"""jit-safe incremental-GE row selection for the n > K erasure path.

The seed's `select_decodable_rows` was a host-side numpy greedy loop
that recomputed the rank of the picked prefix from scratch for every
candidate row — O(n·K) full eliminations, with a device->host sync per
row.  This module replaces it with a single forward elimination pass
that maintains pivot state on-device:

* ``B`` (K, K): the reduced basis — row c holds the (normalized) basis
  vector whose pivot sits in column c, zero if that pivot is unfilled.
  ``B`` is kept in *reduced* row-echelon form, so reducing a candidate
  row against the whole basis is one GF mat-vec.
* A candidate row is selected iff its reduction against the basis is
  nonzero (i.e. it is independent of everything selected so far) —
  exactly the greedy matroid rule of the old helper, so the selected
  index set is identical.

Everything is `lax.fori_loop` + `lax.cond`: no host numpy, no sync,
usable inside jit and under vmap over batches of coding matrices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.gf import get_field


@functools.lru_cache(maxsize=None)
def _select_fn(s: int):
    field = get_field(s)

    @jax.jit
    def run(A: jnp.ndarray):
        A = jnp.asarray(A, jnp.uint8)
        n, K = A.shape

        def body(i, state):
            B, filled, sel, count = state
            row = A[i]
            # one-shot reduction: B is in RREF, so subtracting
            # row[c]·B[c] for every filled pivot c zeroes row at all
            # filled pivot columns in a single pass.
            coeffs = jnp.where(filled, row, jnp.uint8(0))
            red = row ^ field.matmul(coeffs[None, :], B)[0]
            nz = red != 0
            found = jnp.any(nz)
            piv = jnp.argmax(nz)                # first nonzero column

            def pick(args):
                B, filled, sel, count = args
                newrow = field.mul(red, field.inv(red[piv]))
                # keep RREF: clear column `piv` from existing rows
                fac = B[:, piv]
                B = B ^ field.mul(fac[:, None], newrow[None, :])
                B = B.at[piv].set(newrow)
                filled = filled.at[piv].set(True)
                sel = sel.at[count].set(i)
                return B, filled, sel, count + 1

            return jax.lax.cond(found, pick, lambda a: a,
                                (B, filled, sel, count))

        state = (
            jnp.zeros((K, K), jnp.uint8),       # basis B
            jnp.zeros((K,), jnp.bool_),         # filled pivots
            jnp.zeros((K,), jnp.int32),         # selected row indices
            jnp.int32(0),                       # selected count
        )
        _, _, sel, count = jax.lax.fori_loop(0, n, body, state)
        return count == K, sel, count

    return run


def incremental_select(A: jnp.ndarray, s: int):
    """Greedily pick K independent rows of A (n, K) over GF(2^s).

    Returns ``(ok, idx, count)``: `ok` — scalar bool, full column rank
    reached; `idx` — (K,) int32 selected row indices in scan order
    (positions >= count are 0-padded, matching the old helper); `count`
    — number of independent rows found (== rank of A, capped at K).

    Row 1 below is 2·row 0 over GF(2^8), so the selector skips it:

    >>> import jax.numpy as jnp
    >>> A = jnp.array([[1, 0], [2, 0], [0, 3]], dtype=jnp.uint8)
    >>> ok, idx, count = incremental_select(A, 8)
    >>> bool(ok), idx.tolist(), int(count)
    (True, [0, 2], 2)
    """
    return _select_fn(s)(A)
