"""StreamDecoder: incremental Gaussian elimination over an arrival stream.

The batch decoder (:meth:`CodingEngine.decode`) needs the whole coded
stack in hand before it can start; under a real network the server
hears tuples *one at a time*, and Prop. 1 says it is done the moment
any K linearly-independent ones have arrived — typically the first
~K arrivals.  This module turns that proposition into an executable
state machine:

* The decoder maintains the same reduced-basis state as
  ``engine/select.py:incremental_select`` — ``B`` (K, K) in reduced
  row-echelon form with one row per filled pivot column — extended
  with a payload block ``Y`` (K, L) that receives *identical* row
  operations.  Invariant: for every filled pivot p, ``B[p]·P = Y[p]``.
* ``push(a, c)`` reduces one arrival against the basis in a single GF
  mat-vec (B is RREF, so one pass clears every filled pivot).  A
  nonzero residual is normalized and inserted; a zero residual is a
  *redundant* arrival (linearly dependent — the stream analogue of a
  duplicate blind-box draw) and is dropped.
* When ``rank == K``, B has become the identity, so ``Y`` *is* the
  decoded packet matrix — no final solve.  GF arithmetic is exact,
  hence the result is bit-identical to the batch decode of any
  full-rank subset (property-tested in tests/test_sim.py).
* ``ingest`` consumes a whole block of arrivals as ONE jitted
  ``lax.scan`` dispatch and returns the rank trajectory — the bulk
  path `repro.sim` uses so a round's rank evolution costs one
  dispatch, not one per packet.

States: ``FILLING`` (rank < K) -> ``COMPLETE`` (rank == K; further
pushes are no-ops).  ``decoded_at`` records the 1-based arrival count
at which rank K was reached — the measured Prop.-1 draw count.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gf import get_field
from repro.core.rlnc import EncodedBatch

from .select import reduce_insert


@functools.lru_cache(maxsize=None)
def _push_fn(s: int):
    field = get_field(s)

    @jax.jit
    def push(B, Y, filled, a, c):
        B, Y, filled, found = reduce_insert(field, B, Y, filled, a, c)
        return B, Y, filled, found

    return push


@functools.lru_cache(maxsize=None)
def _ingest_fn(s: int):
    field = get_field(s)

    @jax.jit
    def ingest(B, Y, filled, A_rows, C_rows):
        def body(carry, ac):
            B, Y, filled = carry
            a, c = ac
            B, Y, filled, _ = reduce_insert(field, B, Y, filled, a, c)
            return (B, Y, filled), jnp.sum(filled).astype(jnp.int32)

        (B, Y, filled), ranks = jax.lax.scan(
            body, (B, Y, filled), (A_rows, C_rows))
        return B, Y, filled, ranks

    return ingest


class StreamDecoder:
    """Consume coded tuples in arrival order; decode at rank K.

    ``L`` is the payload width in symbols (0 = track rank only, e.g.
    for the network simulator's draw counting).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.gf import get_field
    >>> f = get_field(8)
    >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
    >>> A = f.random_elements(jax.random.PRNGKey(0), (5, 3))
    >>> C = f.matmul(A, P)
    >>> dec = StreamDecoder(K=3, L=4, s=8)
    >>> for g in range(5):                 # arrivals, one at a time
    ...     _ = dec.push(A[g], C[g])
    ...     if dec.complete:
    ...         break
    >>> ok, P_hat = dec.decode()
    >>> bool(ok) and (P_hat == P).all().item(), dec.decoded_at
    (True, 3)
    """

    def __init__(self, K: int, L: int = 0, s: int = 8):
        self.K, self.L, self.s = int(K), int(L), int(s)
        self.field = get_field(s)
        self._B = jnp.zeros((self.K, self.K), jnp.uint8)
        self._Y = jnp.zeros((self.K, self.L), jnp.uint8)
        self._filled = jnp.zeros((self.K,), jnp.bool_)
        self.arrivals = 0          # tuples consumed
        self.decoded_at: Optional[int] = None   # arrival count at rank K

    # -- state ------------------------------------------------------------

    @property
    def rank(self) -> int:
        return int(jnp.sum(self._filled))

    @property
    def complete(self) -> bool:
        return self.decoded_at is not None

    @property
    def state(self) -> str:
        return "COMPLETE" if self.complete else "FILLING"

    # -- consumption ------------------------------------------------------

    def _payload(self, c) -> jnp.ndarray:
        if c is None:
            return jnp.zeros((self.L,), jnp.uint8)
        return jnp.asarray(c, jnp.uint8)

    def push(self, a, c=None) -> int:
        """Consume one arrival (coding vector `a`, payload `c`).

        Returns the rank after the arrival.  Pushes after COMPLETE are
        counted but ignored (the server has already decoded)."""
        self.arrivals += 1
        if self.complete:
            return self.K
        self._B, self._Y, self._filled, _ = _push_fn(self.s)(
            self._B, self._Y, self._filled,
            jnp.asarray(a, jnp.uint8), self._payload(c))
        r = self.rank
        if r == self.K:
            self.decoded_at = self.arrivals
        return r

    def ingest(self, A_rows, C_rows=None) -> np.ndarray:
        """Consume a block of arrivals as one scan dispatch.

        Returns the (g,) rank-after-each-arrival trajectory; updates
        ``decoded_at`` with the first arrival index reaching K."""
        A_rows = jnp.asarray(A_rows, jnp.uint8)
        g = A_rows.shape[0]
        if C_rows is None:
            C_rows = jnp.zeros((g, self.L), jnp.uint8)
        prior = self.arrivals
        already = self.complete
        self._B, self._Y, self._filled, ranks = _ingest_fn(self.s)(
            self._B, self._Y, self._filled, A_rows,
            jnp.asarray(C_rows, jnp.uint8))
        self.arrivals += g
        ranks = np.asarray(ranks)
        if not already and ranks.size and ranks[-1] == self.K:
            self.decoded_at = prior + int(np.argmax(ranks == self.K)) + 1
        return ranks

    # -- the result -------------------------------------------------------

    def decode(self) -> tuple[bool, Optional[jnp.ndarray]]:
        """(ok, P_hat).  At rank K the basis is the identity, so the
        payload block is already the decoded packet matrix."""
        if not self.complete:
            return False, None
        return True, self._Y

    def basis(self) -> jnp.ndarray:
        """The current reduced basis (diagnostics / tests)."""
        return self._B


def stream_decode(batch: EncodedBatch, s: int, order=None
                  ) -> tuple[bool, Optional[jnp.ndarray], int]:
    """Decode an EncodedBatch by feeding its rows in arrival order.

    `order` permutes the rows (default: transmission order).  Returns
    ``(ok, P_hat, consumed)`` where `consumed` is the number of
    arrivals the server actually needed — the rank-K prefix length
    (`decoded_at`; n when rank K was never reached).

    The whole batch goes through one `ingest` scan dispatch: arrivals
    past the rank-K prefix reduce to zero against the completed basis
    and are no-ops, so the decode is identical to stopping at the
    prefix while avoiding a dispatch + host sync per arrival.
    """
    K = batch.K
    dec = StreamDecoder(K=K, L=batch.C.shape[1], s=s)
    if order is None:
        dec.ingest(batch.A, batch.C)
    else:
        idx = jnp.asarray(np.asarray(order), jnp.int32)
        dec.ingest(batch.A[idx], batch.C[idx])
    ok, P_hat = dec.decode()
    return bool(ok), P_hat, (dec.decoded_at if dec.complete
                             else dec.arrivals)
