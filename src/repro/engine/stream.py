"""StreamDecoder: incremental Gaussian elimination over an arrival stream.

The batch decoder (:meth:`CodingEngine.decode`) needs the whole coded
stack in hand before it can start; under a real network the server
hears tuples *one at a time*, and Prop. 1 says it is done the moment
any K linearly-independent ones have arrived — typically the first
~K arrivals.  This module turns that proposition into an executable
state machine:

* The decoder maintains the same reduced-basis state as
  ``engine/select.py:incremental_select`` — ``B`` (K, K) in reduced
  row-echelon form with one row per filled pivot column — extended
  with a payload block ``Y`` (K, L) that receives *identical* row
  operations.  Invariant: for every filled pivot p, ``B[p]·P = Y[p]``.
* ``push(a, c)`` reduces one arrival against the basis in a single GF
  mat-vec (B is RREF, so one pass clears every filled pivot).  A
  nonzero residual is normalized and inserted; a zero residual is a
  *redundant* arrival (linearly dependent — the stream analogue of a
  duplicate blind-box draw) and is dropped.
* When ``rank == K``, B has become the identity, so ``Y`` *is* the
  decoded packet matrix — no final solve.  GF arithmetic is exact,
  hence the result is bit-identical to the batch decode of any
  full-rank subset (property-tested in tests/test_sim.py).
* ``ingest`` consumes a whole block of arrivals as ONE jitted
  ``lax.scan`` dispatch and returns the rank trajectory — the bulk
  path `repro.sim` uses so a round's rank evolution costs one
  dispatch, not one per packet.

States: ``FILLING`` (rank < K) -> ``COMPLETE`` (rank == K; further
pushes are no-ops).  ``decoded_at`` records the 1-based arrival count
at which rank K was reached — the measured Prop.-1 draw count.

The reduced basis doubles as a byzantine tripwire: a *dependent*
arrival whose payload residual is nonzero violates the invariant
B[p]·P = Y[p] and proves corruption somewhere on the stream (see
``reduce_insert``).  Block ingests flag such arrivals for free
(``inconsistent`` / ``first_inconsistent_at``); per-arrival ``push``
keeps checking after COMPLETE only when constructed with
``detect=True`` (the extra dispatches are pure verification).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gf import get_field
from repro.core.rlnc import EncodedBatch, SeededBatch
from repro.core.seeds import expand_rows

from .select import reduce_insert


@functools.lru_cache(maxsize=None)
def _push_fn(s: int):
    field = get_field(s)

    @jax.jit
    def push(B, Y, filled, a, c):
        B, Y, filled, found, bad = reduce_insert(field, B, Y, filled,
                                                 a, c)
        return B, Y, filled, found, bad

    return push


@functools.lru_cache(maxsize=None)
def _ingest_fn(s: int):
    field = get_field(s)

    @jax.jit
    def ingest(B, Y, filled, A_rows, C_rows):
        def body(carry, ac):
            B, Y, filled = carry
            a, c = ac
            B, Y, filled, _, bad = reduce_insert(field, B, Y, filled,
                                                 a, c)
            return (B, Y, filled), (jnp.sum(filled).astype(jnp.int32),
                                    bad)

        (B, Y, filled), (ranks, bads) = jax.lax.scan(
            body, (B, Y, filled), (A_rows, C_rows))
        return B, Y, filled, ranks, bads

    return ingest


@functools.lru_cache(maxsize=None)
def _ingest_seeded_fn(s: int, K: int):
    """Seed-addressed ingest: rows regenerated inside the scan body.

    Only 4 bytes of coding metadata per arrival ever cross into the
    dispatch — the K-symbol row exists transiently in-register per
    scan step.  `col_mask` zeroes coefficients of absent sources (the
    simulator's dropout columns) before reduction, matching the
    materialized path's ``rows[:, ~live] = 0``."""
    field = get_field(s)

    @jax.jit
    def ingest(B, Y, filled, seeds, C_rows, col_mask):
        def body(carry, sc):
            B, Y, filled = carry
            seed, c = sc
            a = expand_rows(seed[None], K, s)[0]
            a = jnp.where(col_mask, a, jnp.uint8(0))
            B, Y, filled, _, bad = reduce_insert(field, B, Y, filled,
                                                 a, c)
            return (B, Y, filled), (jnp.sum(filled).astype(jnp.int32),
                                    bad)

        (B, Y, filled), (ranks, bads) = jax.lax.scan(
            body, (B, Y, filled), (seeds, C_rows))
        return B, Y, filled, ranks, bads

    return ingest


class StreamDecoder:
    """Consume coded tuples in arrival order; decode at rank K.

    ``L`` is the payload width in symbols (0 = track rank only, e.g.
    for the network simulator's draw counting).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.gf import get_field
    >>> f = get_field(8)
    >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
    >>> A = f.random_elements(jax.random.PRNGKey(0), (5, 3))
    >>> C = f.matmul(A, P)
    >>> dec = StreamDecoder(K=3, L=4, s=8)
    >>> for g in range(5):                 # arrivals, one at a time
    ...     _ = dec.push(A[g], C[g])
    ...     if dec.complete:
    ...         break
    >>> ok, P_hat = dec.decode()
    >>> bool(ok) and (P_hat == P).all().item(), dec.decoded_at
    (True, 3)
    """

    def __init__(self, K: int, L: int = 0, s: int = 8,
                 detect: bool = False):
        self.K, self.L, self.s = int(K), int(L), int(s)
        self.detect = bool(detect)
        self.field = get_field(s)
        self._B = jnp.zeros((self.K, self.K), jnp.uint8)
        self._Y = jnp.zeros((self.K, self.L), jnp.uint8)
        self._filled = jnp.zeros((self.K,), jnp.bool_)
        self.arrivals = 0          # tuples consumed
        self.decoded_at: Optional[int] = None   # arrival count at rank K
        self.inconsistent = 0      # provably-corrupted arrivals seen
        self.first_inconsistent_at: Optional[int] = None

    # -- state ------------------------------------------------------------

    @property
    def rank(self) -> int:
        return int(jnp.sum(self._filled))

    @property
    def complete(self) -> bool:
        return self.decoded_at is not None

    @property
    def state(self) -> str:
        return "COMPLETE" if self.complete else "FILLING"

    @property
    def tampered(self) -> bool:
        """True once any arrival proved inconsistent with the basis —
        the stream carried at least one corrupted tuple."""
        return self.inconsistent > 0

    # -- consumption ------------------------------------------------------

    def _payload(self, c) -> jnp.ndarray:
        if c is None:
            return jnp.zeros((self.L,), jnp.uint8)
        return jnp.asarray(c, jnp.uint8)

    def push(self, a, c=None) -> int:
        """Consume one arrival (coding vector `a`, payload `c`).

        `a` may be a scalar uint32 *seed* instead of a (K,) row — the
        seed-addressed wire format — in which case the row is
        regenerated here (`repro.core.seeds`).  Returns the rank after
        the arrival.  Pushes after COMPLETE are counted but ignored
        (the server has already decoded) unless ``detect=True``, in
        which case they are still reduced so payload-inconsistent
        redundancy keeps tripping the byzantine counter."""
        self.arrivals += 1
        if self.complete and not self.detect:
            return self.K
        a = jnp.asarray(a)
        if a.dtype == jnp.uint32 and a.ndim == 0:
            a = expand_rows(a[None], self.K, self.s)[0]
        self._B, self._Y, self._filled, _, bad = _push_fn(self.s)(
            self._B, self._Y, self._filled,
            jnp.asarray(a, jnp.uint8), self._payload(c))
        if bool(bad):
            self.inconsistent += 1
            if self.first_inconsistent_at is None:
                self.first_inconsistent_at = self.arrivals
        r = self.rank
        if r == self.K and self.decoded_at is None:
            self.decoded_at = self.arrivals
        return r

    def _record_block(self, g: int, prior: int, already: bool,
                      ranks, bads) -> np.ndarray:
        self.arrivals += g
        ranks = np.asarray(ranks)
        bads = np.asarray(bads)
        if not already and ranks.size and ranks[-1] == self.K:
            self.decoded_at = prior + int(np.argmax(ranks == self.K)) + 1
        if bads.any():
            self.inconsistent += int(bads.sum())
            if self.first_inconsistent_at is None:
                self.first_inconsistent_at = prior + int(
                    np.argmax(bads)) + 1
        return ranks

    def ingest(self, A_rows, C_rows=None) -> np.ndarray:
        """Consume a block of arrivals as one scan dispatch.

        A 1-D uint32 `A_rows` is treated as a block of row *seeds*
        (see :meth:`ingest_seeded`).  Returns the (g,) rank-after-
        each-arrival trajectory; updates ``decoded_at`` with the first
        arrival index reaching K."""
        A_rows = jnp.asarray(A_rows)
        if A_rows.ndim == 1 and A_rows.dtype == jnp.uint32:
            return self.ingest_seeded(A_rows, C_rows)
        A_rows = jnp.asarray(A_rows, jnp.uint8)
        g = A_rows.shape[0]
        if C_rows is None:
            C_rows = jnp.zeros((g, self.L), jnp.uint8)
        prior = self.arrivals
        already = self.complete
        self._B, self._Y, self._filled, ranks, bads = _ingest_fn(self.s)(
            self._B, self._Y, self._filled, A_rows,
            jnp.asarray(C_rows, jnp.uint8))
        return self._record_block(g, prior, already, ranks, bads)

    def ingest_seeded(self, seeds, C_rows=None,
                      col_mask=None) -> np.ndarray:
        """Consume a block of seed-addressed arrivals (one dispatch).

        `seeds` is (g,) uint32; each row is regenerated *inside* the
        jitted scan, so per-arrival coding metadata is 4 bytes instead
        of K symbols.  `col_mask` (K,) bool zeroes the coefficients of
        absent sources before reduction — the simulator's dropout
        semantics, bit-identical to masking the materialized rows."""
        seeds = jnp.asarray(seeds, jnp.uint32)
        g = seeds.shape[0]
        if C_rows is None:
            C_rows = jnp.zeros((g, self.L), jnp.uint8)
        mask = (jnp.ones((self.K,), jnp.bool_) if col_mask is None
                else jnp.asarray(col_mask, jnp.bool_))
        prior = self.arrivals
        already = self.complete
        self._B, self._Y, self._filled, ranks, bads = _ingest_seeded_fn(
            self.s, self.K)(
            self._B, self._Y, self._filled, seeds,
            jnp.asarray(C_rows, jnp.uint8), mask)
        return self._record_block(g, prior, already, ranks, bads)

    # -- the result -------------------------------------------------------

    def decode(self) -> tuple[bool, Optional[jnp.ndarray]]:
        """(ok, P_hat).  At rank K the basis is the identity, so the
        payload block is already the decoded packet matrix."""
        if not self.complete:
            return False, None
        return True, self._Y

    def basis(self) -> jnp.ndarray:
        """The current reduced basis (diagnostics / tests)."""
        return self._B


@functools.lru_cache(maxsize=None)
def _bank_fns(s: int, K: int):
    """The multi-tenant tick kernel: one scan over a padded row block.

    Shared by the batched (vmapped over job slots — ONE dispatch per
    tick regardless of how many jobs are in flight) and the sequential
    (one dispatch per slot; the serving benchmark's baseline) paths, so
    the two differ only in dispatch granularity, never in math.

    Each scan step consumes one wire tuple that may be *either* format:
    ``use_seed`` selects between the materialized (K,) row and the row
    regenerated in-dispatch from the 4-byte seed (`repro.core.seeds` —
    counter-based, so expanding to the bank-wide padded K and masking
    is bit-identical to expanding to the job's own K).  ``valid=False``
    rows (scheduler padding) and masked columns (per-job generation
    size / dropout) are zeroed before reduction — a zero row has zero
    residual, so padding is an exact no-op on [B | Y] and on the rank
    trajectory."""
    field = get_field(s)

    def one(B, Y, filled, rows, seeds, use_seed, valid, C, col_mask):
        def body(carry, x):
            B, Y, filled = carry
            row, seed, use, ok, c = x
            gen = expand_rows(seed[None], K, s)[0]
            a = jnp.where(use, gen, row)
            a = jnp.where(col_mask & ok, a, jnp.uint8(0))
            B, Y, filled, _, _ = reduce_insert(field, B, Y, filled,
                                               a, c)
            return (B, Y, filled), jnp.sum(filled).astype(jnp.int32)

        (B, Y, filled), ranks = jax.lax.scan(
            body, (B, Y, filled), (rows, seeds, use_seed, valid, C))
        return B, Y, filled, ranks

    return jax.jit(jax.vmap(one)), jax.jit(one)


class DecoderBank:
    """J :class:`StreamDecoder` states advanced by one batched dispatch.

    The serving layer (`repro.serve`) holds many federated rounds in
    flight at once; each *slot* of the bank is one job's reduced-basis
    state ``[B | Y]`` (exactly the single-job invariant documented
    above), stacked along a leading jobs axis.  :meth:`ingest` consumes
    a padded ``(slots, g)`` tick block of arrivals for ALL jobs as one
    vmapped `lax.scan` — the continuous-batching analogue of a
    chunked-prefill step, with per-job basis state playing the role of
    per-request prefix state.

    All slots share the bank-wide padded shape (``K`` coefficient
    columns, ``L`` payload symbols); a job with a smaller generation
    size ``k`` simply masks the columns beyond ``k`` (and a shorter
    payload zero-pads — GF row ops never mix columns, so padding
    columns stay zero).  Bit-exactness vs. per-job StreamDecoders is
    property-tested in tests/test_serve.py.

    >>> import jax.numpy as jnp
    >>> bank = DecoderBank(slots=2, K=2, L=4)
    >>> bank.open(0, k=2), bank.open(1, k=2)
    (0, 1)
    >>> P = jnp.arange(8, dtype=jnp.uint8).reshape(2, 4)
    >>> eye = jnp.eye(2, dtype=jnp.uint8)
    >>> ranks = bank.ingest(rows=jnp.stack([eye, eye]),
    ...                     C=jnp.stack([P, P ^ 1]))
    >>> ranks.tolist()                     # both jobs, one dispatch
    [[1, 2], [1, 2]]
    >>> bank.complete.tolist()
    [True, True]
    >>> bool((bank.payload(1) == (P ^ 1)).all())
    True
    """

    def __init__(self, slots: int, K: int, L: int, s: int = 8):
        self.slots, self.K, self.L, self.s = (int(slots), int(K),
                                              int(L), int(s))
        self._B = jnp.zeros((self.slots, self.K, self.K), jnp.uint8)
        self._Y = jnp.zeros((self.slots, self.K, self.L), jnp.uint8)
        self._filled = jnp.zeros((self.slots, self.K), jnp.bool_)
        self._col_mask = np.zeros((self.slots, self.K), bool)
        self._k = np.zeros((self.slots,), np.int64)   # 0 = slot closed
        self._l = np.zeros((self.slots,), np.int64)
        self.dispatches = 0

    # -- slot lifecycle ---------------------------------------------------

    def open(self, slot: int, k: int, l: Optional[int] = None,
             col_mask=None) -> int:
        """(Re)initialize `slot` for a job with generation size `k`.

        `col_mask` (k,) bool masks dropped sources (the StreamDecoder
        ``col_mask`` semantics); columns beyond `k` are always masked.
        Returns the slot index."""
        slot = int(slot)
        if not 0 < k <= self.K:
            raise ValueError(f"job k={k} exceeds bank K={self.K}")
        l = self.L if l is None else int(l)
        if l > self.L:
            raise ValueError(f"job L={l} exceeds bank L={self.L}")
        self._B = self._B.at[slot].set(jnp.uint8(0))
        self._Y = self._Y.at[slot].set(jnp.uint8(0))
        self._filled = self._filled.at[slot].set(False)
        mask = np.zeros((self.K,), bool)
        mask[:k] = True if col_mask is None else np.asarray(col_mask,
                                                            bool)[:k]
        self._col_mask[slot] = mask
        self._k[slot] = k
        self._l[slot] = l
        return slot

    def close(self, slot: int) -> None:
        """Retire a slot (its state stays until the next `open`)."""
        self._k[int(slot)] = 0

    @property
    def open_slots(self) -> np.ndarray:
        return np.nonzero(self._k > 0)[0]

    @property
    def target(self) -> np.ndarray:
        """(slots,) per-job target rank (0 for closed slots)."""
        return self._k.copy()

    @property
    def rank(self) -> np.ndarray:
        return np.asarray(jnp.sum(self._filled, axis=1))

    @property
    def complete(self) -> np.ndarray:
        """(slots,) — open slots whose basis reached their target rank."""
        return (self._k > 0) & (self.rank >= self._k)

    # -- the tick ---------------------------------------------------------

    def _tick_args(self, rows, seeds, use_seed, valid, C):
        g = None
        for arr in (rows, seeds, C):
            if arr is not None:
                g = int(jnp.asarray(arr).shape[1])
                break
        if g is None:
            raise ValueError("need rows, seeds, or C to size the tick")
        J, K, L = self.slots, self.K, self.L
        rows = (jnp.zeros((J, g, K), jnp.uint8) if rows is None
                else jnp.asarray(rows, jnp.uint8))
        seeds = (jnp.zeros((J, g), jnp.uint32) if seeds is None
                 else jnp.asarray(seeds, jnp.uint32))
        use_seed = (jnp.zeros((J, g), jnp.bool_) if use_seed is None
                    else jnp.asarray(use_seed, jnp.bool_))
        valid = (jnp.ones((J, g), jnp.bool_) if valid is None
                 else jnp.asarray(valid, jnp.bool_))
        C = (jnp.zeros((J, g, L), jnp.uint8) if C is None
             else jnp.asarray(C, jnp.uint8))
        return rows, seeds, use_seed, valid, C

    def ingest(self, rows=None, seeds=None, use_seed=None, valid=None,
               C=None, *, batched: bool = True) -> np.ndarray:
        """Advance every slot by one padded (slots, g) tick block.

        `rows` (slots, g, K) uint8 materialized coding rows, `seeds`
        (slots, g) uint32 row seeds, `use_seed` (slots, g) bool format
        selector per tuple, `valid` (slots, g) bool padding mask, `C`
        (slots, g, L) uint8 payloads; omitted arrays default to zeros
        (and `valid` to all-true).  Returns the (slots, g) rank-after-
        each-arrival trajectory.

        ``batched=True`` advances all slots in ONE vmapped dispatch;
        ``batched=False`` runs the identical per-slot kernel once per
        slot holding work — the sequential per-job baseline the serving
        benchmark measures against.  Both paths are bit-identical.
        """
        rows, seeds, use_seed, valid, C = self._tick_args(
            rows, seeds, use_seed, valid, C)
        mask = jnp.asarray(self._col_mask)
        batched_fn, single_fn = _bank_fns(self.s, self.K)
        if batched:
            self._B, self._Y, self._filled, ranks = batched_fn(
                self._B, self._Y, self._filled, rows, seeds, use_seed,
                valid, C, mask)
            self.dispatches += 1
            return np.asarray(ranks)
        ranks = np.zeros(valid.shape, np.int32)
        work = np.asarray(jnp.any(valid, axis=1))
        base = self.rank
        for j in range(self.slots):
            if not work[j]:
                ranks[j] = base[j]
                continue
            Bj, Yj, fj, rj = single_fn(
                self._B[j], self._Y[j], self._filled[j], rows[j],
                seeds[j], use_seed[j], valid[j], C[j], mask[j])
            self._B = self._B.at[j].set(Bj)
            self._Y = self._Y.at[j].set(Yj)
            self._filled = self._filled.at[j].set(fj)
            self.dispatches += 1
            ranks[j] = np.asarray(rj)
        return ranks

    # -- results ----------------------------------------------------------

    def payload(self, slot: int) -> jnp.ndarray:
        """The decoded (k, l) packet matrix of a complete slot.

        At rank k the basis restricted to the job's columns is the
        identity, so rows [0, k) of Y are the decoded packets."""
        slot = int(slot)
        k, l = int(self._k[slot]), int(self._l[slot])
        return self._Y[slot, :k, :l]

    def basis(self, slot: int) -> jnp.ndarray:
        return self._B[int(slot)]


def stream_decode(batch, s: int, order=None
                  ) -> tuple[bool, Optional[jnp.ndarray], int]:
    """Decode an EncodedBatch (or SeededBatch) row-by-row in arrival order.

    `order` permutes the rows (default: transmission order).  Returns
    ``(ok, P_hat, consumed)`` where `consumed` is the number of
    arrivals the server actually needed — the rank-K prefix length
    (`decoded_at`; n when rank K was never reached).

    The whole batch goes through one `ingest` scan dispatch: arrivals
    past the rank-K prefix reduce to zero against the completed basis
    and are no-ops, so the decode is identical to stopping at the
    prefix while avoiding a dispatch + host sync per arrival.  A
    :class:`SeededBatch` flows through the seed-addressed scan — its
    rows are regenerated in-dispatch and the decode is bit-identical
    to streaming the expanded batch.
    """
    K = batch.K
    rows = batch.seeds if isinstance(batch, SeededBatch) else batch.A
    dec = StreamDecoder(K=K, L=batch.C.shape[1], s=s)
    if order is None:
        dec.ingest(rows, batch.C)
    else:
        idx = jnp.asarray(np.asarray(order), jnp.int32)
        dec.ingest(rows[idx], batch.C[idx])
    ok, P_hat = dec.decode()
    return bool(ok), P_hat, (dec.decoded_at if dec.complete
                             else dec.arrivals)
