"""CodingEngine: the unified encode -> channel -> select -> decode spine.

FedNC's entire round cost is the coded matmul C = A·P and its GE
inverse (paper §II-B, Alg. 1).  The seed scattered that hot path over
four layers with host-side Python in the middle; this engine owns it
end to end as one jit-first, chunked, multi-device program:

* **batched packetization** — client pytrees are stacked once and
  byte/symbol-split under `vmap` (core.packets.pytrees_to_packets); no
  per-client Python loop.
* **registry dispatch** — the kernel is a name resolved through
  repro.engine.registry (`EngineConfig.kernel`), replacing the
  `impl="auto"|"jnp"|"pallas"` strings that used to live in three
  places.
* **chunked streaming executor** — the lane dimension L is tiled into
  fixed `chunk_l`-symbol blocks.  Each block is dispatched
  asynchronously, so models larger than VMEM stream through the Pallas
  kernel, and in `round()` the decode of chunk i overlaps the encode
  of chunk i+1 (no cross-chunk data dependency is ever introduced).
* **jit-safe selection** — the n > K erasure path picks K independent
  rows with the incremental-GE pass in repro.engine.select, entirely
  on-device.
* **multi-device lanes** — given a mesh (launch.mesh), the kernel is
  wrapped in `shard_map` sharding L across the configured axis; lanes
  are embarrassingly parallel, so there is no communication.
* **fused channels and recoding** — channels that expose their action
  on the row space (`plan_transform` -> RowGather/RowMix) are folded
  into the stream: the erasure pattern / composed relay mix is decided
  on the tiny (n, K) coding matrix first, then encode, channel, and
  decode run as ONE chunk-streamed dispatch.  `recode()` is the
  network-interior relay operation (Prop. 2), and `multi_edge_round()`
  runs the whole hierarchical topology (paper §III) as a single fused
  dispatch in the global coding-vector space.

`core.fednc.fednc_round`, the federation strategies, and
`core.hierarchy` are thin adapters over this class.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import packets as pkt
from repro.core import seeds as seedlib
from repro.core.channel import ChannelReport, RowGather, RowMix, RowTamper
from repro.core.gf import get_field, invert
from repro.core.rlnc import EncodedBatch, SeededBatch

from .defaults import DEFAULT_CHUNK_L
from .registry import (is_seeded_kernel, materialized_kernel_name,
                       resolve_kernel, seeded_kernel_name)
from .select import incremental_select


def _is_seed_rows(A) -> bool:
    """True iff the row operand is a (n,) uint32 seed vector.

    The two wire formats are structurally disjoint — materialized rows
    are a 2-D uint8 matrix, seed vectors are 1-D uint32 — so dispatch
    is unambiguous."""
    arr = jnp.asarray(A)
    return arr.ndim == 1 and arr.dtype == jnp.uint32


@dataclass(frozen=True)
class EngineConfig:
    """Everything the coding spine needs, in one hashable record."""

    s: int = 8                   # field size (symbol bits), paper Table I
    kernel: str = "auto"         # registry name (see engine.registry)
    chunk_l: int = DEFAULT_CHUNK_L   # symbols per streamed chunk; 0 = off
    lane_axis: Optional[str] = "data"  # mesh axis sharding L (if meshed)
    extra_tuples: int = 0        # send K + extra coded tuples
    systematic: bool = False     # identity-prefixed coding matrix
    coding_density: float = 1.0  # <1.0 = sparse RLNC coefficients


@dataclass(frozen=True)
class EngineRound:
    """Outcome of one engine round (the coded math, pre-aggregation)."""

    ok: bool
    packets: Optional[jnp.ndarray]   # (K, L) decoded symbols when ok
    report: Any = None               # ChannelReport when a channel ran
    # redundant-rank cross-check (round(verify=True)): True = every
    # redundant delivered tuple is consistent with the decode, False =
    # corruption detected, None = not checked / no redundancy to check
    verified: Optional[bool] = None


#: shared default so signatures avoid calls in argument defaults
#: (ruff B008) and `get_engine()` == `get_engine(EngineConfig())` in
#: the lru_cache
_DEFAULT_CONFIG = EngineConfig()


class CodingEngine:
    """Owns the full RLNC pipeline for one EngineConfig (+ optional mesh)."""

    def __init__(self, config: EngineConfig = _DEFAULT_CONFIG,
                 mesh: Any = None):
        self.config = config
        self.mesh = mesh
        self.kernel_name, self._kernel = resolve_kernel(config.kernel)
        # A seeded kernel only covers the *encode* side (rows derived
        # from seeds); decode/recode mix with arbitrary materialized
        # matrices (A^-1, R), which run through the kernel's
        # materialized sibling.  Both siblings are always resolved so
        # any engine can consume either packet format; `self.seeded`
        # governs which format round()/encode() *produce*.
        self.seeded = is_seeded_kernel(self.kernel_name)
        if self.seeded:
            self._seed_kernel = self._kernel
            _, self._mat_kernel = resolve_kernel(
                materialized_kernel_name(self.kernel_name))
        else:
            _, self._seed_kernel = resolve_kernel(
                seeded_kernel_name(self.kernel_name))
            self._mat_kernel = self._kernel
        self.field = get_field(config.s)
        self._dispatch: dict[bool, tuple] = {}   # built lazily, once
        # per-engine metrics; engine.dispatches counts L-sized kernel
        # dispatches (monotonic; benchmarks diff it around a round)
        self.metrics = obs.MetricsRegistry()
        self._dispatches = self.metrics.counter("engine.dispatches")

    @property
    def dispatch_count(self) -> int:
        """L-sized kernel dispatches issued so far (monotonic)."""
        return self._dispatches.value

    # -- packetization ----------------------------------------------------

    def packetize(self, client_params: Sequence[Any]
                  ) -> tuple[jnp.ndarray, pkt.PacketSpec]:
        """K client pytrees -> (K, L) symbol matrix, vmap-batched."""
        return pkt.pytrees_to_packets(client_params, s=self.config.s)

    def unpacketize(self, P_hat: jnp.ndarray, spec: pkt.PacketSpec):
        """(K, L) decoded symbols -> stacked pytree (leading K axis)."""
        return pkt.packets_to_pytrees(P_hat, spec)

    # -- coding matrices --------------------------------------------------

    def coding_matrix(self, key, n: int, K: int) -> jnp.ndarray:
        from repro.core import rlnc
        cfg = self.config
        if cfg.systematic:
            return rlnc.systematic_coding_matrix(key, n, K, cfg.s)
        if cfg.coding_density < 1.0:
            return rlnc.sparse_coding_matrix(key, n, K, cfg.s,
                                             density=cfg.coding_density)
        return rlnc.random_coding_matrix(key, n, K, cfg.s)

    def coding_seeds(self, key, n: int) -> jnp.ndarray:
        """n uint32 row seeds — the seed-addressed coding "matrix".

        Only the plain uniform RLNC draw has a seeded representation;
        systematic / sparse rows cannot be derived from a 4-byte seed.
        """
        cfg = self.config
        if cfg.systematic or cfg.coding_density < 1.0:
            raise ValueError(
                "seeded coding vectors require plain uniform RLNC "
                "(systematic=False, coding_density=1.0)")
        return seedlib.draw_seeds(key, n)

    def expand_seeds(self, seeds, K: int) -> jnp.ndarray:
        """Materialize the (n, K) rows a seed vector addresses.

        The decode/oracle-side bridge: row-space work (selection,
        inversion, recoding) happens on this tiny matrix while the
        L-sized payload products stay seed-addressed."""
        return seedlib.expand_rows_jit(seeds, K, self.config.s)

    # -- chunked / sharded executor ---------------------------------------

    def _mesh_kernel(self, seeded: bool = False):
        """The registry kernel, shard_map-wrapped over the lane axis.

        Built (and jitted) once per engine (separately for the seeded
        encode kernel and the materialized mixing kernel), so repeat
        chunks dispatch from the compile cache instead of re-tracing
        the shard_map."""
        if seeded in self._dispatch:
            return self._dispatch[seeded]
        kern = self._seed_kernel if seeded else self._mat_kernel
        mesh, axis = self.mesh, self.config.lane_axis
        if mesh is None or axis is None or axis not in mesh.axis_names \
                or mesh.shape[axis] == 1:
            self._dispatch[seeded] = (kern, 1)
            return self._dispatch[seeded]
        from jax.experimental.shard_map import shard_map
        from repro.launch.sharding import coded_spec, replicated_spec
        size = int(mesh.shape[axis])
        s = self.config.s
        # the row operand replicates either way: a tiny (n, K) matrix,
        # or the even tinier (n,) seed vector
        row_spec = replicated_spec(1 if seeded else 2)
        sharded = jax.jit(shard_map(
            lambda a, p: kern(a, p, s=s), mesh=mesh,
            in_specs=(row_spec, coded_spec(2, mesh, axis=axis)),
            out_specs=coded_spec(2, mesh, axis=axis),
            check_rep=False,
        ))
        self._dispatch[seeded] = (sharded, size)
        return self._dispatch[seeded]

    def _chunks(self, L: int) -> tuple[int, int]:
        """(chunk width, count) covering L after padding."""
        cl = self.config.chunk_l
        if cl <= 0 or L <= cl:
            return max(L, 1), 1
        return cl, -(-L // cl)

    def matmul(self, A: jnp.ndarray, P: jnp.ndarray, *,
               stage: str = "encode") -> jnp.ndarray:
        """C = A·P, chunk-streamed through the configured kernel.

        Chunks are dispatched eagerly (JAX async dispatch), so chunk
        i+1 is enqueued while chunk i still executes on-device.  On a
        seeded engine, pass the (n,) uint32 seed vector as `A` to run
        the seeded encode kernel (rows regenerated in-kernel).
        `stage` labels the per-chunk trace spans (``engine.<stage>``)
        when tracing is enabled.
        """
        return self._stream(A, P, enc_seeded=_is_seed_rows(A),
                            stage=stage)

    def _stream(self, A, P, A_post=None, *, enc_seeded: bool = False,
                stage: str = "encode", post_stage: str = "decode"):
        """Run the kernel chunk-by-chunk over the lane dim of P.

        With `A_post` (the decode mixing matrix), each chunk is pushed
        through *both* matmuls before the next chunk is dispatched:
        C_i = A·P_i then A_post·C_i.  No cross-chunk dependency exists,
        so the decode of chunk i overlaps the encode of chunk i+1 via
        async dispatch.  Returns A·P, or A_post·A·P when given.

        With ``enc_seeded`` the first operand is the (n,) uint32 seed
        vector and the encode matmul runs through the seeded kernel —
        coefficient rows are regenerated inside the kernel per chunk,
        so the coding matrix never rides along with the payload.  The
        `A_post` mixing (decode) product always uses the materialized
        kernel.
        """
        enc_kernel, shards = self._mesh_kernel(enc_seeded)
        post_kernel, _ = self._mesh_kernel(False)
        s = self.config.s
        if A_post is not None:
            n_out = A_post.shape[0]
        else:
            n_out = A.shape[0]
        L = P.shape[1]
        if L == 0:
            return jnp.zeros((n_out, 0), jnp.uint8)

        tr = obs.get_tracer()

        def mm(kernel, M, X, label, chunk):
            self._dispatches.inc()
            if not tr.enabled:
                return kernel(M, X, s=s) if shards == 1 \
                    else kernel(M, X)
            # traced: fence the chunk so the span measures device time
            # (the untraced path above keeps async-dispatch pipelining)
            with tr.span(f"engine.{label}", cat="engine",
                         chunk=chunk) as sp:
                return sp.fence(kernel(M, X, s=s) if shards == 1
                                else kernel(M, X))

        cl, nc = self._chunks(L)
        cl += (-cl) % shards            # lane-shardable chunk width
        if nc == 1 and cl == L:
            out = mm(enc_kernel, A, P, stage, 0)
            return mm(post_kernel, A_post, out, post_stage, 0) \
                if A_post is not None else out
        Lp = cl * nc
        Pp = jnp.pad(P, ((0, 0), (0, Lp - L))) if Lp != L else P
        outs = []
        for c in range(nc):
            block = jax.lax.dynamic_slice_in_dim(Pp, c * cl, cl, axis=1)
            enc = mm(enc_kernel, A, block, stage, c)
            outs.append(mm(post_kernel, A_post, enc, post_stage, c)
                        if A_post is not None else enc)
        return jnp.concatenate(outs, axis=1)[:, :L]

    # -- pipeline stages --------------------------------------------------

    def encode(self, P: jnp.ndarray, A: jnp.ndarray):
        """C = A·P as an EncodedBatch (chunk-streamed).

        P is the (K, L) packet matrix (K clients, L symbols each), A an
        (n, K) coding matrix over GF(2^s) — usually from
        :meth:`coding_matrix`.  Passing a (n,) uint32 seed vector (from
        :meth:`coding_seeds`) instead runs the seeded kernel and
        returns a :class:`SeededBatch` — 4-byte headers on the wire,
        rows regenerated in-kernel.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> A = eng.coding_matrix(jax.random.PRNGKey(0), n=3, K=3)
        >>> batch = eng.encode(P, A)
        >>> batch.A.shape, batch.C.shape
        ((3, 3), (3, 4))
        """
        if _is_seed_rows(A):
            return self.encode_seeded(P, A)
        return EncodedBatch(A=jnp.asarray(A, jnp.uint8),
                            C=self.matmul(A, P))

    def encode_seeded(self, P: jnp.ndarray, seeds: jnp.ndarray
                      ) -> SeededBatch:
        """C = rows(seeds)·P without materializing the coding matrix.

        Bit-exact vs. ``encode(P, expand_seeds(seeds, K)).C`` — same
        Threefry stream, evaluated inside the kernel per chunk.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> seeds = eng.coding_seeds(jax.random.PRNGKey(0), n=3)
        >>> sb = eng.encode_seeded(P, seeds)
        >>> mat = eng.encode(P, eng.expand_seeds(seeds, 3))
        >>> (sb.C == mat.C).all().item()
        True
        """
        seeds = jnp.asarray(seeds, jnp.uint32)
        C = self._stream(seeds, P, enc_seeded=True)
        return SeededBatch(seeds=seeds, C=C, K=int(P.shape[0]))

    def recode(self, batch, key, n_out: int) -> EncodedBatch:
        """Relay recoding (paper Prop. 2): emit `n_out` fresh random
        combinations of the received tuples without decoding.

        The relay draws R (n_out, n) over GF(2^s) and forwards
        (R·A, R·C); coding vectors compose linearly, so downstream
        decoders treat the result exactly like first-hop tuples.  Both
        products run through the registry kernel, chunk-streamed
        (`recode_with` for a caller-supplied R).  A :class:`SeededBatch`
        input is accepted — seed-expansion of the tiny (n, K) rows
        happens at the relay, and the output rows are *materialized*:
        a composed row R·A is not derivable from any 4-byte seed, so
        Prop. 2 semantics survive while only first-hop traffic enjoys
        the seeded header.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> batch = eng.encode(P, eng.coding_matrix(jax.random.PRNGKey(0), 3, 3))
        >>> relay = eng.recode(batch, jax.random.PRNGKey(1), n_out=4)
        >>> relay.A.shape, relay.C.shape          # 4 fresh combinations
        ((4, 3), (4, 4))
        >>> ok, P_hat = eng.decode(relay)         # still decodes to P
        >>> bool(ok) and (P_hat == P).all().item()
        True
        """
        R = self.field.random_elements(key, (n_out, batch.n))
        return self.recode_with(R, batch)

    def recode_with(self, R: jnp.ndarray, batch) -> EncodedBatch:
        """Recode with an explicit mixing matrix: (R·A, R·C).

        η sequential hops compose by linearity — recoding with
        R_η···R_1 (one call) is bit-identical to η calls in sequence;
        `core.channel.MultiHopChannel` relies on exactly that."""
        R = jnp.asarray(R, jnp.uint8)
        if isinstance(batch, SeededBatch):
            batch = batch.expand(self.config.s)
        return EncodedBatch(A=self.matmul(R, batch.A, stage="recode"),
                            C=self.matmul(R, batch.C, stage="recode"))

    def select(self, batch) -> tuple[jnp.ndarray, EncodedBatch]:
        """Pick K independent tuples out of n >= K, fully on-device."""
        if isinstance(batch, SeededBatch):
            batch = batch.expand(self.config.s)
        with obs.get_tracer().span("engine.select", cat="engine",
                                   n=int(batch.n)) as sp:
            ok, idx, _ = incremental_select(batch.A, self.config.s)
            sp.fence(idx)
        return ok, EncodedBatch(A=batch.A[idx], C=batch.C[idx])

    def decode(self, batch) -> tuple[bool, Optional[jnp.ndarray]]:
        """(ok, P_hat): select (if n > K), invert A, stream A^-1·C.

        GF arithmetic is exact, so inverting the (tiny) K x K coding
        matrix and streaming A^-1·C chunk-wise is bit-identical to the
        seed's monolithic Gaussian elimination over [A | C].

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> batch = eng.encode(P, eng.coding_matrix(jax.random.PRNGKey(0), 5, 3))
        >>> ok, P_hat = eng.decode(batch[jnp.array([0, 2, 4])])  # 2 erased
        >>> bool(ok) and (P_hat == P).all().item()
        True

        A :class:`SeededBatch` is accepted directly: the receiver
        regenerates the tiny (n, K) coding matrix from the 4-byte
        headers (the L-sized payload never carried the rows)."""
        if isinstance(batch, SeededBatch):
            batch = batch.expand(self.config.s)
        K = batch.K
        if batch.n < K:
            return False, None
        ok = jnp.bool_(True)
        if batch.n > K:
            ok, batch = self.select(batch)
        with obs.get_tracer().span("engine.invert", cat="engine",
                                   K=K) as sp:
            ok_inv, A_inv = invert(self.field, batch.A)
            sp.fence(A_inv)
        if not bool(ok & ok_inv):
            return False, None
        return True, self.matmul(A_inv, batch.C, stage="decode")

    def decode_verified(self, batch) -> tuple[bool, Optional[jnp.ndarray],
                                              Optional[bool]]:
        """(ok, P_hat, verified): decode plus the byzantine cross-check.

        Decoding consumes only K of the n delivered tuples; the n - K
        *redundant* ones are free integrity checks: re-encode P_hat
        with each redundant coding row and compare the payload digest
        against what the channel delivered.  Any mismatch proves some
        tuple was corrupted — an honest channel (lossy, reordering, or
        recoding) delivers only exact GF combinations, so every
        redundant row of an uncorrupted stream reproduces its payload
        bit-for-bit.

        ``verified`` is True when every redundant tuple checks out,
        False on any mismatch, and None when there is no redundancy to
        check (n == K after selection) — corruption can then slip
        through undetected, which is why the byzantine benchmarks run
        with ``extra_tuples > 0``.  Note False flags the *round*, not a
        row: a forged row may itself decode cleanly and instead poison
        the check of an honest redundant row; either way the server
        knows to discard and re-request.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> batch = eng.encode(P, eng.coding_matrix(jax.random.PRNGKey(0), 5, 3))
        >>> ok, P_hat, verified = eng.decode_verified(batch)
        >>> bool(ok), (P_hat == P).all().item(), verified
        (True, True, True)
        >>> bad = EncodedBatch(A=batch.A, C=batch.C.at[4, 0].set(batch.C[4, 0] ^ 1))
        >>> eng.decode_verified(bad)[2]
        False
        """
        import hashlib

        if isinstance(batch, SeededBatch):
            batch = batch.expand(self.config.s)
        K, n = batch.K, batch.n
        if n < K:
            return False, None, None
        ok, idx, _ = incremental_select(batch.A, self.config.s)
        ok_inv, A_inv = invert(self.field, batch.A[idx])
        if not bool(ok & ok_inv):
            return False, None, None
        P_hat = self.matmul(A_inv, batch.C[idx], stage="decode")
        red = np.setdiff1d(np.arange(n), np.asarray(idx))
        if red.size == 0:
            return True, P_hat, None
        red_j = jnp.asarray(red, jnp.int32)
        pred = self.matmul(batch.A[red_j], P_hat, stage="verify")
        pred_np = np.asarray(pred)
        got_np = np.asarray(batch.C[red_j])
        verified = all(
            hashlib.sha256(pred_np[i].tobytes()).digest()
            == hashlib.sha256(got_np[i].tobytes()).digest()
            for i in range(red.size))
        return True, P_hat, bool(verified)

    # -- fused round internals --------------------------------------------

    def _fused_ideal_round(self, P: jnp.ndarray, A: jnp.ndarray,
                           seeds: Optional[jnp.ndarray] = None
                           ) -> EngineRound:
        """Lossless-delivery tail: resolve invertibility on the tiny
        (n, K) problem, then stream A_inv·(A_sel·P) in one dispatch.

        When `seeds` is given, A is its expansion; row-space planning
        (selection, inversion) runs on A while the L-sized encode
        product runs the seeded kernel on the matching seed subset."""
        n, K = A.shape
        if n < K:
            return EngineRound(False, None, None)
        tr = obs.get_tracer()
        ok = jnp.bool_(True)
        if n > K:
            with tr.span("engine.select", cat="engine", n=n) as sp:
                ok, idx, _ = incremental_select(A, self.config.s)
                sp.fence(idx)
            A_sel = A[idx]
            enc = seeds[idx] if seeds is not None else A_sel
        else:
            A_sel = A
            enc = seeds if seeds is not None else A
        with tr.span("engine.invert", cat="engine", K=K) as sp:
            ok_inv, A_inv = invert(self.field, A_sel)
            sp.fence(A_inv)
        if not bool(ok & ok_inv):
            return EngineRound(False, None, None)
        # encode only the selected rows — the ideal channel delivers
        # everything, so unselected erasure-headroom rows are dead work
        # and A_inv·(A_sel·P) is the exact decode.
        P_hat = self._stream(enc, P, A_post=A_inv,
                             enc_seeded=seeds is not None)
        return EngineRound(True, P_hat, None)

    def _expand_err(self, err_seeds, which, width: int) -> jnp.ndarray:
        """Materialize adversarial error rows `which` of a RowTamper
        seed vector at `width` symbols (K for coding rows, L for
        payloads) — same Threefry expansion as the wire format."""
        sel = jnp.asarray(np.asarray(err_seeds)[which], jnp.uint32)
        return seedlib.expand_rows_jit(sel, width, self.config.s)

    def _fused_tamper_round(self, P: jnp.ndarray, A: jnp.ndarray,
                            plan: RowTamper,
                            seeds: Optional[jnp.ndarray] = None,
                            verify: bool = False) -> EngineRound:
        """RowTamper tail: byzantine corruption folded into the stream.

        All n tuples are delivered, rows `plan.idx` XOR-ed with
        seed-expanded noise.  Selection and inversion run on the
        *received* (corrupted) matrix — the server cannot tell a forged
        row from an honest one — while the encode leg replays the true
        rows, so the decode output is exactly what a stage-wise
        receiver of the corrupted batch would compute:

            P_hat = A_rx[sel]^-1 · C_rx[sel]
                  = A_inv·(A_true[sel]·P)  ^  A_inv·E[sel]

        with E the (sparse) payload-error matrix; only its few nonzero
        rows are ever expanded to L symbols.  With `verify`, the
        redundant delivered rows are cross-checked against P_hat
        (:meth:`decode_verified` semantics, residual form) at the cost
        of two extra (n-K)-row streamed products.
        """
        n, K = A.shape
        L = P.shape[1]
        tr = obs.get_tracer()
        idx_np = np.asarray(plan.idx, np.int64)
        with tr.span("engine.transform", cat="engine", n=n) as sp:
            A_rx = A
            if plan.m and plan.row_seeds is not None:
                idx_t = jnp.asarray(idx_np, jnp.int32)
                A_err = self._expand_err(plan.row_seeds,
                                         np.arange(plan.m), K)
                A_rx = A.at[idx_t].set(A[idx_t] ^ A_err)
            sp.fence(A_rx)
        with tr.span("engine.select", cat="engine", n=n) as sp:
            ok, sel, _ = incremental_select(A_rx, self.config.s)
            sp.fence(sel)
        report = ChannelReport(n, n, bool(ok))
        if not bool(ok):
            return EngineRound(False, None, report)
        with tr.span("engine.invert", cat="engine", K=K) as sp:
            _, A_inv = invert(self.field, A_rx[sel])
            sp.fence(A_inv)
        sel_np = np.asarray(sel, np.int64)
        enc_rows = seeds if seeds is not None else A
        P_hat = self._stream(enc_rows[sel], P, A_post=A_inv,
                             enc_seeded=seeds is not None)
        pos_of = {int(r): j for j, r in enumerate(idx_np)}

        def err_at(rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
            hit = [(j, pos_of[int(r)]) for j, r in enumerate(rows)
                   if int(r) in pos_of]
            return (np.asarray([h[0] for h in hit], np.int64),
                    np.asarray([h[1] for h in hit], np.int64))

        if plan.payload_seeds is not None and L:
            where, which = err_at(sel_np)
            if where.size:
                E = self._expand_err(plan.payload_seeds, which, L)
                P_hat = P_hat ^ self.field.matmul(
                    A_inv[:, jnp.asarray(where, jnp.int32)], E)
        verified = None
        if verify:
            red = np.setdiff1d(np.arange(n), sel_np)
            if red.size:
                red_j = jnp.asarray(red, jnp.int32)
                C_red = self._stream(enc_rows[red_j], P,
                                     enc_seeded=seeds is not None,
                                     stage="verify")
                if plan.payload_seeds is not None and L:
                    where, which = err_at(red)
                    if where.size:
                        E = self._expand_err(plan.payload_seeds, which, L)
                        w = jnp.asarray(where, jnp.int32)
                        C_red = C_red.at[w].set(C_red[w] ^ E)
                resid = self._stream(A_rx[red_j], P_hat,
                                     stage="verify") ^ C_red
                verified = not bool(jnp.any(resid != 0))
        return EngineRound(True, P_hat, report, verified)

    def _fused_channel_round(self, P: jnp.ndarray, A: jnp.ndarray,
                             channel,
                             seeds: Optional[jnp.ndarray] = None,
                             verify: bool = False) -> EngineRound:
        """encode -> channel -> select -> decode as ONE streamed dispatch.

        The channel's `plan_transform` yields its whole action on the
        row space (RowGather erasure pattern / RowMix relay matrix), so
        delivery, selection, and inversion are all resolved on (n, K)-
        sized matrices first.  The L-sized payload then flows through a
        single `_stream` whose A_post composes channel and decode:
        channel simulation overlaps the decode of every chunk, and the
        full coded payload is never materialized between stages.  GF
        algebra is exact and associative, so the result is bit-identical
        to the stage-wise reference.
        """
        n, K = A.shape
        s = self.config.s
        tr = obs.get_tracer()
        plan = channel.plan_transform(n, s)
        if isinstance(plan, RowTamper):
            # byzantine corruption: the whole round (including the
            # redundant-rank cross-check) has its own fused tail
            return self._fused_tamper_round(P, A, plan, seeds, verify)
        with tr.span("engine.transform", cat="engine", n=n) as sp:
            if isinstance(plan, RowGather):
                delivered = int(len(plan.idx))
                if delivered < K:
                    return EngineRound(
                        False, None, ChannelReport(n, delivered, False))
                idx = jnp.asarray(plan.idx, jnp.int32)
                A_rx = A[idx]
            elif isinstance(plan, RowMix):
                delivered = int(plan.R.shape[0])
                A_rx = self.field.matmul(plan.R, A)
            else:
                raise TypeError(
                    f"unsupported channel plan {type(plan).__name__}")
            sp.fence(A_rx)
        with tr.span("engine.select", cat="engine", n=delivered) as sp:
            ok, sel, _ = incremental_select(A_rx, s)
            sp.fence(sel)
        report = ChannelReport(n, delivered, bool(ok))
        if not bool(ok):
            return EngineRound(False, None, report)
        with tr.span("engine.invert", cat="engine", K=K) as sp:
            _, A_inv = invert(self.field, A_rx[sel])  # sel independent
            sp.fence(A_inv)
        if isinstance(plan, RowGather):
            A_enc, A_post = A[idx[sel]], A_inv
            if seeds is not None:
                A_enc = seeds[idx[sel]]
        else:
            # RowMix touches every source row, so the full seed vector
            # feeds the encode; the relay composition R folds into the
            # materialized A_post (composed rows have no seed).
            A_enc, A_post = A, self.field.matmul(A_inv, plan.R[sel])
            if seeds is not None:
                A_enc = seeds
        P_hat = self._stream(A_enc, P, A_post=A_post,
                             enc_seeded=seeds is not None)
        return EngineRound(True, P_hat, report)

    def _stagewise_channel_round(self, P: jnp.ndarray, A: jnp.ndarray,
                                 channel,
                                 verify: bool = False) -> EngineRound:
        """Fallback for channels without `plan_transform`: materialize
        the coded payload and run the stages in order."""
        batch = self.encode(P, A)
        batch, report = channel.transmit_encoded(batch, self.config.s)
        if not report.decodable:
            return EngineRound(False, None, report)
        if verify:
            ok, P_hat, verified = self.decode_verified(batch)
            return EngineRound(bool(ok), P_hat, report, verified)
        ok, P_hat = self.decode(batch)
        return EngineRound(bool(ok), P_hat, report)

    def _run_round(self, P: jnp.ndarray, A: jnp.ndarray, channel,
                   seeds: Optional[jnp.ndarray] = None,
                   verify: bool = False) -> EngineRound:
        """Shared channel-dispatch tail of `round`/`multi_edge_round`.

        `seeds`, when given, is the seed vector whose expansion is `A`;
        the fused paths then run their encode leg through the seeded
        kernel.  The stage-wise fallback materializes (it already has
        A), which is bit-identical by construction.  `verify` requests
        the redundant-rank cross-check (honored by the stage-wise and
        RowTamper paths; honest fused plans leave ``verified=None``)."""
        if channel is None:
            return self._fused_ideal_round(P, A, seeds)
        if hasattr(channel, "plan_transform"):
            return self._fused_channel_round(P, A, channel, seeds,
                                             verify)
        return self._stagewise_channel_round(P, A, channel, verify)

    # -- the full round ---------------------------------------------------

    def round(self, P: jnp.ndarray, key, channel=None, *,
              verify: bool = False) -> EngineRound:
        """encode -> (channel) -> select -> decode for one packet matrix.

        Ideal channel (None): the coding matrix is drawn, selected, and
        inverted *before* any L-sized work, then encode and decode of
        each chunk are interleaved in one stream — decode of chunk i
        overlaps encode of chunk i+1, and a singular draw costs O(K^3),
        not O(K·L).  Channels exposing `plan_transform` (erasure,
        multi-hop recode) are fused the same way; others fall back to
        the stage-wise path.  Bit-exact vs. the jnp-oracle reference.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> out = eng.round(P, jax.random.PRNGKey(0))
        >>> out.ok and (out.packets == P).all().item()
        True
        """
        K, L = P.shape
        n = K + self.config.extra_tuples
        with obs.get_tracer().span("engine.round", cat="engine",
                                   K=K, L=L, n=n) as sp:
            if self.seeded:
                # seeded engine: draw 4-byte row seeds; the tiny
                # expansion drives row-space planning while the L-sized
                # encode stays seed-addressed inside the kernel.
                seeds = self.coding_seeds(key, n)
                out = self._run_round(P, self.expand_seeds(seeds, K),
                                      channel, seeds=seeds,
                                      verify=verify)
            else:
                A = self.coding_matrix(key, n, K)
                out = self._run_round(P, A, channel, verify=verify)
            sp.fence(out.packets)
        return out

    # -- the fused hierarchical round (paper §III) ------------------------

    def multi_edge_coding_matrix(self, key, edges: Sequence[Sequence[int]],
                                 K: int, n_out: Sequence[int]
                                 ) -> jnp.ndarray:
        """Stacked global-space coding matrix of a whole edge tier.

        Edge e (serving clients `edges[e]`, a subset of range(K)) draws
        its (n_out[e], K_e) local mixing matrix with
        ``jax.random.fold_in(key, e)`` — the same stream the per-edge
        reference consumes — and its rows are embedded at that edge's
        client columns of the global K-wide coding-vector space.  Rows
        of different edges never overlap in support, so the stack is
        the block-structured matrix of paper §III's hierarchy.
        """
        blocks = []
        for e, ids in enumerate(edges):
            cols = jnp.asarray(tuple(int(i) for i in ids), jnp.int32)
            A_local = self.field.random_elements(
                jax.random.fold_in(key, e), (int(n_out[e]), len(ids)))
            A_g = jnp.zeros((int(n_out[e]), K), jnp.uint8)
            blocks.append(A_g.at[:, cols].set(A_local))
        return jnp.concatenate(blocks, axis=0)

    def multi_edge_round(self, P: jnp.ndarray, key,
                         edges: Sequence[Sequence[int]], *,
                         spare_per_edge: int = 0,
                         wan_channel=None,
                         verify: bool = False) -> EngineRound:
        """One fused hierarchical round: E edge encodes + WAN + decode.

        Instead of E separate `encode` re-entries (one per edge server)
        followed by a stage-wise channel and decode, the whole topology
        becomes one dispatch: every edge's local encode is a row block
        of :meth:`multi_edge_coding_matrix` in the global coding-vector
        space, the WAN channel (erasures / multi-hop recoding) is
        planned on the row space, and the single chunk-streamed
        `_stream` call runs encode, channel, and decode per chunk —
        decode of chunk i overlaps encode of chunk i+1.  Bit-exact vs.
        the per-edge reference (`core.hierarchy`, fused=False).

        `edges` lists each edge server's client indices (a partition of
        range(K)); each edge emits K_e + `spare_per_edge` combinations,
        so WAN erasures are repaired without re-contacting clients.

        >>> import jax, jax.numpy as jnp
        >>> eng = CodingEngine(EngineConfig(s=8, kernel="jnp"))
        >>> P = jnp.arange(12, dtype=jnp.uint8).reshape(3, 4)
        >>> out = eng.multi_edge_round(P, jax.random.PRNGKey(0),
        ...                            edges=[(0, 1), (2,)],
        ...                            spare_per_edge=1)
        >>> out.ok and (out.packets == P).all().item()
        True
        """
        K, L = P.shape
        n_out = [len(ids) + spare_per_edge for ids in edges]
        with obs.get_tracer().span("engine.multi_edge_round",
                                   cat="engine", K=K, L=L,
                                   edges=len(edges)) as sp:
            A = self.multi_edge_coding_matrix(key, edges, K, n_out)
            out = self._run_round(P, A, wan_channel, verify=verify)
            sp.fence(out.packets)
        return out


@functools.lru_cache(maxsize=None)
def get_engine(config: EngineConfig = _DEFAULT_CONFIG) -> CodingEngine:
    """Process-wide engine cache keyed by (hashable) EngineConfig.

    Meshed engines are not cached (Mesh is unhashable); construct
    CodingEngine(config, mesh=...) directly for multi-device runs.
    """
    return CodingEngine(config)
