"""CodingEngine: the unified encode -> channel -> select -> decode spine.

FedNC's entire round cost is the coded matmul C = A·P and its GE
inverse (paper §II-B, Alg. 1).  The seed scattered that hot path over
four layers with host-side Python in the middle; this engine owns it
end to end as one jit-first, chunked, multi-device program:

* **batched packetization** — client pytrees are stacked once and
  byte/symbol-split under `vmap` (core.packets.pytrees_to_packets); no
  per-client Python loop.
* **registry dispatch** — the kernel is a name resolved through
  repro.engine.registry (`EngineConfig.kernel`), replacing the
  `impl="auto"|"jnp"|"pallas"` strings that used to live in three
  places.
* **chunked streaming executor** — the lane dimension L is tiled into
  fixed `chunk_l`-symbol blocks.  Each block is dispatched
  asynchronously, so models larger than VMEM stream through the Pallas
  kernel, and in `round()` the decode of chunk i overlaps the encode
  of chunk i+1 (no cross-chunk data dependency is ever introduced).
* **jit-safe selection** — the n > K erasure path picks K independent
  rows with the incremental-GE pass in repro.engine.select, entirely
  on-device.
* **multi-device lanes** — given a mesh (launch.mesh), the kernel is
  wrapped in `shard_map` sharding L across the configured axis; lanes
  are embarrassingly parallel, so there is no communication.

`core.fednc.fednc_round`, the federation strategies, and
`core.hierarchy` are thin adapters over this class.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import packets as pkt
from repro.core.gf import get_field, invert
from repro.core.rlnc import EncodedBatch
from .defaults import DEFAULT_CHUNK_L
from .registry import resolve_kernel
from .select import incremental_select


@dataclass(frozen=True)
class EngineConfig:
    """Everything the coding spine needs, in one hashable record."""

    s: int = 8                   # field size (symbol bits), paper Table I
    kernel: str = "auto"         # registry name (see engine.registry)
    chunk_l: int = DEFAULT_CHUNK_L   # symbols per streamed chunk; 0 = off
    lane_axis: Optional[str] = "data"  # mesh axis sharding L (if meshed)
    extra_tuples: int = 0        # send K + extra coded tuples
    systematic: bool = False     # identity-prefixed coding matrix
    coding_density: float = 1.0  # <1.0 = sparse RLNC coefficients


@dataclass(frozen=True)
class EngineRound:
    """Outcome of one engine round (the coded math, pre-aggregation)."""

    ok: bool
    packets: Optional[jnp.ndarray]   # (K, L) decoded symbols when ok
    report: Any = None               # ChannelReport when a channel ran


class CodingEngine:
    """Owns the full RLNC pipeline for one EngineConfig (+ optional mesh)."""

    def __init__(self, config: EngineConfig = EngineConfig(),
                 mesh: Any = None):
        self.config = config
        self.mesh = mesh
        self.kernel_name, self._kernel = resolve_kernel(config.kernel)
        self.field = get_field(config.s)
        self._dispatch: Optional[tuple] = None   # built lazily, once

    # -- packetization ----------------------------------------------------

    def packetize(self, client_params: Sequence[Any]
                  ) -> tuple[jnp.ndarray, pkt.PacketSpec]:
        """K client pytrees -> (K, L) symbol matrix, vmap-batched."""
        return pkt.pytrees_to_packets(client_params, s=self.config.s)

    def unpacketize(self, P_hat: jnp.ndarray, spec: pkt.PacketSpec):
        """(K, L) decoded symbols -> stacked pytree (leading K axis)."""
        return pkt.packets_to_pytrees(P_hat, spec)

    # -- coding matrices --------------------------------------------------

    def coding_matrix(self, key, n: int, K: int) -> jnp.ndarray:
        from repro.core import rlnc
        cfg = self.config
        if cfg.systematic:
            return rlnc.systematic_coding_matrix(key, n, K, cfg.s)
        if cfg.coding_density < 1.0:
            return rlnc.sparse_coding_matrix(key, n, K, cfg.s,
                                             density=cfg.coding_density)
        return rlnc.random_coding_matrix(key, n, K, cfg.s)

    # -- chunked / sharded executor ---------------------------------------

    def _mesh_kernel(self):
        """The registry kernel, shard_map-wrapped over the lane axis.

        Built (and jitted) once per engine, so repeat chunks dispatch
        from the compile cache instead of re-tracing the shard_map."""
        if self._dispatch is not None:
            return self._dispatch
        mesh, axis = self.mesh, self.config.lane_axis
        if mesh is None or axis is None or axis not in mesh.axis_names \
                or mesh.shape[axis] == 1:
            self._dispatch = (self._kernel, 1)
            return self._dispatch
        from jax.experimental.shard_map import shard_map
        from repro.launch.sharding import coded_spec, replicated_spec
        size = int(mesh.shape[axis])
        kern = self._kernel
        s = self.config.s
        sharded = jax.jit(shard_map(
            lambda a, p: kern(a, p, s=s), mesh=mesh,
            in_specs=(replicated_spec(2), coded_spec(2, mesh, axis=axis)),
            out_specs=coded_spec(2, mesh, axis=axis),
            check_rep=False,
        ))
        self._dispatch = (sharded, size)
        return self._dispatch

    def _chunks(self, L: int) -> tuple[int, int]:
        """(chunk width, count) covering L after padding."""
        cl = self.config.chunk_l
        if cl <= 0 or L <= cl:
            return max(L, 1), 1
        return cl, -(-L // cl)

    def matmul(self, A: jnp.ndarray, P: jnp.ndarray) -> jnp.ndarray:
        """C = A·P, chunk-streamed through the configured kernel.

        Chunks are dispatched eagerly (JAX async dispatch), so chunk
        i+1 is enqueued while chunk i still executes on-device.
        """
        return self._stream(A, P)

    def _stream(self, A, P, A_post=None):
        """Run the kernel chunk-by-chunk over the lane dim of P.

        With `A_post` (the decode mixing matrix), each chunk is pushed
        through *both* matmuls before the next chunk is dispatched:
        C_i = A·P_i then A_post·C_i.  No cross-chunk dependency exists,
        so the decode of chunk i overlaps the encode of chunk i+1 via
        async dispatch.  Returns A·P, or A_post·A·P when given.
        """
        kernel, shards = self._mesh_kernel()
        s = self.config.s
        n_out = (A_post if A_post is not None else A).shape[0]
        L = P.shape[1]
        if L == 0:
            return jnp.zeros((n_out, 0), jnp.uint8)

        def mm(M, X):
            return kernel(M, X, s=s) if shards == 1 else kernel(M, X)

        cl, nc = self._chunks(L)
        cl += (-cl) % shards            # lane-shardable chunk width
        if nc == 1 and cl == L:
            out = mm(A, P)
            return mm(A_post, out) if A_post is not None else out
        Lp = cl * nc
        Pp = jnp.pad(P, ((0, 0), (0, Lp - L))) if Lp != L else P
        outs = []
        for c in range(nc):
            block = jax.lax.dynamic_slice_in_dim(Pp, c * cl, cl, axis=1)
            enc = mm(A, block)
            outs.append(mm(A_post, enc) if A_post is not None else enc)
        return jnp.concatenate(outs, axis=1)[:, :L]

    # -- pipeline stages --------------------------------------------------

    def encode(self, P: jnp.ndarray, A: jnp.ndarray) -> EncodedBatch:
        """C = A·P as an EncodedBatch (chunk-streamed)."""
        return EncodedBatch(A=jnp.asarray(A, jnp.uint8),
                            C=self.matmul(A, P))

    def select(self, batch: EncodedBatch
               ) -> tuple[jnp.ndarray, EncodedBatch]:
        """Pick K independent tuples out of n >= K, fully on-device."""
        ok, idx, _ = incremental_select(batch.A, self.config.s)
        return ok, EncodedBatch(A=batch.A[idx], C=batch.C[idx])

    def decode(self, batch: EncodedBatch
               ) -> tuple[bool, Optional[jnp.ndarray]]:
        """(ok, P_hat): select (if n > K), invert A, stream A^-1·C.

        GF arithmetic is exact, so inverting the (tiny) K x K coding
        matrix and streaming A^-1·C chunk-wise is bit-identical to the
        seed's monolithic Gaussian elimination over [A | C].
        """
        K = batch.K
        if batch.n < K:
            return False, None
        ok = jnp.bool_(True)
        if batch.n > K:
            ok, batch = self.select(batch)
        ok_inv, A_inv = invert(self.field, batch.A)
        if not bool(ok & ok_inv):
            return False, None
        return True, self.matmul(A_inv, batch.C)

    # -- the full round ---------------------------------------------------

    def round(self, P: jnp.ndarray, key, channel=None) -> EngineRound:
        """encode -> (channel) -> select -> decode for one packet matrix.

        Ideal channel (None): the coding matrix is drawn, selected, and
        inverted *before* any L-sized work, then encode and decode of
        each chunk are interleaved in one stream — decode of chunk i
        overlaps encode of chunk i+1, and a singular draw costs O(K^3),
        not O(K·L).  Bit-exact vs. the jnp-oracle reference path.
        """
        K, L = P.shape
        n = K + self.config.extra_tuples
        A = self.coding_matrix(key, n, K)

        if channel is not None:
            batch = self.encode(P, A)
            batch, report = channel.transmit_encoded(batch, self.config.s)
            if not report.decodable:
                return EngineRound(False, None, report)
            ok, P_hat = self.decode(batch)
            return EngineRound(bool(ok), P_hat, report)

        # ideal path: resolve invertibility on the K-sized problem first
        ok = jnp.bool_(True)
        if n > K:
            ok, idx, _ = incremental_select(A, self.config.s)
            A_sel = A[idx]
        else:
            A_sel = A
        ok_inv, A_inv = invert(self.field, A_sel)
        if not bool(ok & ok_inv):
            return EngineRound(False, None, None)
        # encode only the selected rows — the ideal channel delivers
        # everything, so unselected erasure-headroom rows are dead work
        # and A_inv·(A_sel·P) is the exact decode.
        P_hat = self._stream(A_sel, P, A_post=A_inv)
        return EngineRound(True, P_hat, None)


@functools.lru_cache(maxsize=None)
def get_engine(config: EngineConfig = EngineConfig()) -> CodingEngine:
    """Process-wide engine cache keyed by (hashable) EngineConfig.

    Meshed engines are not cached (Mesh is unhashable); construct
    CodingEngine(config, mesh=...) directly for multi-device runs.
    """
    return CodingEngine(config)
