"""Leaf constants shared by the engine and its core adapters.

This module must stay dependency-free: `repro.core.fednc` imports it at
module level while `repro.engine.engine` imports `repro.core` at module
level — a leaf breaks that cycle for both import orders (submodule
imports from a partially-initialized package are safe; attribute-style
`from repro.engine import ...` is not).
"""

#: default streamed-chunk width, in symbols.  2^18 uint8 symbols =
#: 256 KiB per (row of a) block — far under VMEM with K ~ tens, and a
#: multiple of every (pow2) mesh-axis size and the int32 lane-pack
#: factor.
DEFAULT_CHUNK_L = 1 << 18
