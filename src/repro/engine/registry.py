"""Kernel registry: one dispatch point for every GF coded-matmul path.

The seed scattered backend choice across three stringly-typed sites
(`kernels.ops.gf_matmul(impl=...)`, `rlnc.encode(impl=...)`,
`FedNCConfig.kernel_impl`).  All of them now resolve here.

A *kernel* is a callable ``fn(A, P, *, s) -> C`` computing C = A·P over
GF(2^s) for A (n, K) uint8 and P (K, L) uint8.  The **seeded** family
takes ``(seeds, P)`` instead — seeds (n,) uint32 — and regenerates row
i of the coding matrix from seed i with the counter-based Threefry
stream (`repro.core.seeds.expand_rows`), bit-identical to running the
materialized sibling on the expanded matrix.  Built-in entries (this
table is the source of truth; `scripts/check_docs.py` fails the fast
tier if the documented lists drift from ``available_kernels()``):

======================  ====================================================
``jnp``                 table-based jnp oracle (independent formulation —
                        the correctness reference)
``jnp_clmul``           unpacked carry-less multiply in pure jnp (the
                        Pallas kernel's math, interpret-free)
``jnp_packed``          int32 lane-packed ladder in pure jnp — fastest CPU
                        path (4 symbols per vector lane)
``pallas``              unpacked Pallas TPU kernel (interpret on CPU)
``pallas_packed``       lane-packed Pallas TPU kernel (interpret on CPU)
``jnp_seeded``          seeded table oracle: expand rows, then ``jnp``
``jnp_packed_seeded``   seeded lane-packed ladder, coefficients generated
                        in the k loop (no (n, K) uint8 operand)
``pallas_packed_seeded``  lane-packed Pallas kernel generating its
                        coefficient tile in-register from the seeds ref
``auto``                alias: ``pallas_packed`` on TPU, ``jnp_packed``
                        elsewhere
``auto_seeded``         alias: ``pallas_packed_seeded`` on TPU,
                        ``jnp_packed_seeded`` elsewhere
======================  ====================================================

Downstream projects register custom backends with
:func:`register_kernel` (e.g. a GPU clmul kernel) and select them by
name through :class:`repro.engine.EngineConfig`; pass ``seeded=True``
for backends with the seeds-first signature.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gf2_xor import gf2_matmul_pallas
from repro.kernels.gf_matmul import (gf_matmul_pallas,
                                     gf_matmul_pallas_packed,
                                     gf_matmul_pallas_packed_seeded)

KernelFn = Callable[..., jnp.ndarray]

SEEDED_SUFFIX = "_seeded"
_ALIASES = ("auto", "auto_seeded")

_KERNELS: Dict[str, KernelFn] = {}
_SEEDED: set[str] = set()


def register_kernel(name: str, fn: KernelFn, *,
                    seeded: bool = False,
                    overwrite: bool = False) -> KernelFn:
    """Register a coded-matmul backend under `name`.

    `fn(A, P, *, s)` must return A·P over GF(2^s) as (n, L) uint8,
    bit-exact against the `jnp` table oracle.  With ``seeded=True``
    the first operand is (n,) uint32 row seeds instead of A, and the
    result must be bit-exact against the `jnp_seeded` oracle (i.e.
    the materialized product of ``repro.core.seeds.expand_rows``).
    Registration is process-global; see docs/engine.md for a worked
    custom-backend example (kept out of this doctest so doctest runs
    never mutate the live registry).

    >>> "jnp_packed" in available_kernels()   # built-ins pre-registered
    True
    >>> register_kernel("auto", print)
    Traceback (most recent call last):
        ...
    ValueError: 'auto' is a reserved alias
    """
    if name in _ALIASES:
        raise ValueError(f"{name!r} is a reserved alias")
    if name in _KERNELS and not overwrite:
        raise ValueError(f"kernel {name!r} already registered")
    _KERNELS[name] = fn
    if seeded:
        _SEEDED.add(name)
    else:
        _SEEDED.discard(name)
    return fn


def unregister_kernel(name: str) -> None:
    """Remove a custom backend registered with :func:`register_kernel`.

    Exists so tests (e.g. the contract checker's doctored-kernel
    cases) can restore the process-global registry; unknown names
    raise, aliases cannot be removed.

    >>> unregister_kernel("auto")
    Traceback (most recent call last):
        ...
    ValueError: 'auto' is a reserved alias
    """
    if name in _ALIASES:
        raise ValueError(f"{name!r} is a reserved alias")
    if name not in _KERNELS:
        raise ValueError(f"kernel {name!r} is not registered")
    del _KERNELS[name]
    _SEEDED.discard(name)


def available_kernels() -> tuple[str, ...]:
    return tuple(sorted(_KERNELS)) + _ALIASES


def is_seeded_kernel(name: str) -> bool:
    """True iff `name` (or its 'auto' resolution) takes row seeds."""
    return resolve_kernel_name(name) in _SEEDED


def seeded_kernel_name(name: str) -> str:
    """The seeded sibling of a materialized kernel name.

    >>> seeded_kernel_name("jnp_packed")
    'jnp_packed_seeded'
    >>> seeded_kernel_name("auto")
    'auto_seeded'
    """
    if name == "auto":
        return "auto_seeded"
    resolved = resolve_kernel_name(name)
    if resolved in _SEEDED:
        return resolved
    candidate = resolved + SEEDED_SUFFIX
    if candidate not in _SEEDED:
        # fall back to the family oracle pairing: every materialized
        # kernel's rows expand identically, so jnp_seeded is always a
        # correct (if unfused) sibling
        candidate = "jnp_seeded"
    return candidate


def materialized_kernel_name(name: str) -> str:
    """The materialized sibling of a seeded kernel name.

    >>> materialized_kernel_name("pallas_packed_seeded")
    'pallas_packed'
    >>> materialized_kernel_name("jnp")     # already materialized
    'jnp'
    """
    if name == "auto_seeded":
        return "auto"
    resolved = resolve_kernel_name(name)
    if resolved not in _SEEDED:
        return resolved
    base = resolved[: -len(SEEDED_SUFFIX)] \
        if resolved.endswith(SEEDED_SUFFIX) else resolved
    return base if base in _KERNELS else "jnp"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_kernel_name(name: str) -> str:
    """Resolve the 'auto'/'auto_seeded' aliases for the current backend."""
    if name == "auto":
        return "pallas_packed" if _on_tpu() else "jnp_packed"
    if name == "auto_seeded":
        return "pallas_packed_seeded" if _on_tpu() else "jnp_packed_seeded"
    return name


def resolve_kernel(name: str) -> tuple[str, KernelFn]:
    """(resolved_name, fn) for a registry name; raises on unknown."""
    resolved = resolve_kernel_name(name)
    try:
        return resolved, _KERNELS[resolved]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r}; available: {available_kernels()}"
        ) from None


def gf_matmul(A, P, *, s: int = 8, kernel: str = "auto") -> jnp.ndarray:
    """Convenience: one-shot registry-dispatched C = A·P.

    For a seeded kernel name, `A` is the (n,) uint32 seed vector.

    >>> import jax.numpy as jnp
    >>> A = jnp.array([[1, 2]], dtype=jnp.uint8)
    >>> P = jnp.array([[5], [7]], dtype=jnp.uint8)
    >>> int(gf_matmul(A, P, s=8, kernel="jnp")[0, 0])   # 5 ^ (2·7)
    11
    """
    return resolve_kernel(kernel)[1](A, P, s=s)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------
# The pure-jnp formulations are jitted here (s static) so chunk-streamed
# registry calls dispatch one fused computation per chunk instead of
# op-by-op; the Pallas entry points are already jitted at definition.

@functools.partial(jax.jit, static_argnames=("s",))
def _jnp_kernel(A, P, *, s: int):
    if s == 1:
        return ref.gf2_matmul_ref(A, P)
    return ref.gf_matmul_ref(A, P, s)


@functools.partial(jax.jit, static_argnames=("s",))
def _jnp_clmul_kernel(A, P, *, s: int):
    return ref.gf_matmul_clmul_ref(A, P, s)


@functools.partial(jax.jit, static_argnames=("s",))
def _jnp_packed_kernel(A, P, *, s: int):
    return ref.gf_matmul_packed_ref(A, P, s)


def _pallas_kernel(A, P, *, s: int):
    interpret = not _on_tpu()
    if s == 1:
        return gf2_matmul_pallas(A, P, interpret=interpret)
    return gf_matmul_pallas(A, P, s=s, interpret=interpret)


def _pallas_packed_kernel(A, P, *, s: int):
    return gf_matmul_pallas_packed(A, P, s=s, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("s",))
def _jnp_seeded_kernel(seeds, P, *, s: int):
    return ref.gf_matmul_seeded_ref(seeds, P, s)


@functools.partial(jax.jit, static_argnames=("s",))
def _jnp_packed_seeded_kernel(seeds, P, *, s: int):
    return ref.gf_matmul_packed_seeded_ref(seeds, P, s)


def _pallas_packed_seeded_kernel(seeds, P, *, s: int):
    return gf_matmul_pallas_packed_seeded(seeds, P, s=s,
                                          interpret=not _on_tpu())


register_kernel("jnp", _jnp_kernel)
register_kernel("jnp_clmul", _jnp_clmul_kernel)
register_kernel("jnp_packed", _jnp_packed_kernel)
register_kernel("pallas", _pallas_kernel)
register_kernel("pallas_packed", _pallas_packed_kernel)
register_kernel("jnp_seeded", _jnp_seeded_kernel, seeded=True)
register_kernel("jnp_packed_seeded", _jnp_packed_seeded_kernel,
                seeded=True)
register_kernel("pallas_packed_seeded", _pallas_packed_seeded_kernel,
                seeded=True)
