"""repro.engine — the unified RLNC coding spine.

engine.py   — EngineConfig + CodingEngine: batched packetization,
              chunk-streamed encode/decode, jit-safe selection,
              shard_map lane parallelism, relay recoding, and the
              fused round pipelines (`round`, `multi_edge_round`) that
              fold channel simulation into the encode/decode stream.
registry.py — named kernel registry (single dispatch point replacing
              the impl="auto"|"jnp"|"pallas" strings of the seed).
select.py   — incremental-GE independent-row selector (on-device
              replacement for the host-side numpy greedy loop).

See docs/engine.md for the architecture guide.
"""
from .engine import (CodingEngine, DEFAULT_CHUNK_L, EngineConfig,
                     EngineRound, get_engine)
from .registry import (available_kernels, gf_matmul, register_kernel,
                       resolve_kernel, resolve_kernel_name)
from .select import incremental_select

__all__ = [
    "CodingEngine", "DEFAULT_CHUNK_L", "EngineConfig", "EngineRound",
    "get_engine", "available_kernels", "gf_matmul", "register_kernel",
    "resolve_kernel", "resolve_kernel_name", "incremental_select",
]
