"""repro.engine — the unified RLNC coding spine.

engine.py   — EngineConfig + CodingEngine: batched packetization,
              chunk-streamed encode/decode, jit-safe selection,
              shard_map lane parallelism, relay recoding, and the
              fused round pipelines (`round`, `multi_edge_round`) that
              fold channel simulation into the encode/decode stream.
registry.py — named kernel registry (single dispatch point replacing
              the impl="auto"|"jnp"|"pallas" strings of the seed).
select.py   — incremental-GE independent-row selector (on-device
              replacement for the host-side numpy greedy loop).
stream.py   — StreamDecoder: the selector's reduced-basis state turned
              into an arrival-order consumer that decodes the instant
              rank K is reached (Prop. 1, measured).

See docs/engine.md and docs/simulator.md for the architecture guides.
"""
from .engine import (DEFAULT_CHUNK_L, CodingEngine, EngineConfig,
                     EngineRound, get_engine)
from .registry import (available_kernels, gf_matmul, is_seeded_kernel,
                       materialized_kernel_name, register_kernel,
                       resolve_kernel, resolve_kernel_name,
                       seeded_kernel_name)
from .select import incremental_select
from .stream import DecoderBank, StreamDecoder, stream_decode

__all__ = [
    "CodingEngine", "DEFAULT_CHUNK_L", "EngineConfig", "EngineRound",
    "get_engine", "available_kernels", "gf_matmul", "register_kernel",
    "resolve_kernel", "resolve_kernel_name", "is_seeded_kernel",
    "seeded_kernel_name", "materialized_kernel_name",
    "incremental_select", "DecoderBank", "StreamDecoder",
    "stream_decode",
]
