"""Parsing and naming for the grid's adversary axis.

The axis value is a compact string — ``none``, ``eavesdrop:p``,
``collude:c``, ``byzantine:b`` — because grid axes travel through
scenario names, JSON artifacts, and CLI flags.  This module is the one
place that string is interpreted.

* ``eavesdrop:p`` — a passive attacker intercepting each transmitted
  coded tuple independently with probability p (or, on hierarchical
  cells, tapping a fraction p of the edge→server links).
* ``collude:c``  — c clients pool their own plaintext packets with the
  eavesdropper: c free identity rows in the attacker's basis.
* ``byzantine:b`` — an active interior node corrupting each tuple with
  probability b (see :class:`repro.adversary.ByzantineChannel`).
"""
from __future__ import annotations

from dataclasses import dataclass

KINDS = ("none", "eavesdrop", "collude", "byzantine")


@dataclass(frozen=True)
class AdversarySpec:
    """One parsed adversary-axis value.

    >>> AdversarySpec.parse("eavesdrop:0.5")
    AdversarySpec(kind='eavesdrop', param=0.5)
    >>> AdversarySpec.parse("none").none
    True
    >>> str(AdversarySpec.parse("collude:3"))
    'collude:3'
    >>> AdversarySpec.parse("byzantine:0.1").tag
    'byzantine0.1'
    """

    kind: str = "none"
    param: float = 0.0

    @classmethod
    def parse(cls, text: str) -> "AdversarySpec":
        text = str(text).strip()
        if text in ("", "none"):
            return cls()
        if ":" not in text:
            raise ValueError(f"adversary {text!r}: expected kind:param "
                             f"with kind in {KINDS[1:]}")
        kind, _, raw = text.partition(":")
        if kind not in KINDS[1:]:
            raise ValueError(f"unknown adversary kind {kind!r} "
                             f"(choose from {KINDS})")
        param = float(raw)
        if kind == "collude":
            if param != int(param) or param < 1:
                raise ValueError(
                    f"collude:{raw}: colluder count must be a positive "
                    "integer")
        elif not 0.0 <= param <= 1.0:
            raise ValueError(f"{kind}:{raw}: probability outside [0, 1]")
        return cls(kind=kind, param=param)

    @property
    def none(self) -> bool:
        return self.kind == "none"

    @property
    def count(self) -> int:
        """The integer reading of `param` (colluder count)."""
        return int(self.param)

    @property
    def tag(self) -> str:
        """Name-safe form for scenario names (no ':')."""
        return "none" if self.none else f"{self.kind}{self.param:g}"

    def __str__(self) -> str:
        if self.none:
            return "none"
        if self.kind == "collude":
            return f"collude:{self.count}"
        return f"{self.kind}:{self.param:g}"
