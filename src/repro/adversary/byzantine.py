"""Byzantine packet injection and the recovery loop it forces.

:class:`ByzantineChannel` is an *active* interior node: each coded
tuple crossing it is corrupted independently with probability `rate`.
Corruption is XOR with uniform GF(2^s) noise expanded from 4-byte
counters (`repro.core.seeds`), which makes every mode expressible as
the tiny :class:`repro.core.channel.RowTamper` plan — so the byzantine
round still runs through the engine's fused channel path:

* ``mode="flip"``  — payload symbols flipped, coding row intact: the
  classic corrupted-packet fault.
* ``mode="forge"`` — the coding row is replaced (XOR-with-uniform is
  replacement-by-uniform) while the payload still belongs to the
  *old* row: a forged header that poisons the decode if selected.
* ``mode="both"``  — an arbitrarily hostile relay.

Replayed seeds — the seeded wire format's own attack, where an old
4-byte header is re-sent with a different payload — are not a per-row
XOR (the forged row duplicates another transmitted row), so they are
modeled on the stream path instead: :func:`replayed_seed_batch` builds
the attack batch, and the server-side `StreamDecoder` flags every
replay as an inconsistent dependent arrival.

Detection is the redundant-rank cross-check
(:meth:`CodingEngine.decode_verified` / ``round(verify=True)``), and
:func:`rounds_to_recovery` measures the operational cost: how many
round retries until a verified-clean decode is accepted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeds as seedlib
from repro.core.channel import ChannelReport, RowTamper
from repro.core.gf import get_field, rank as gf_rank
from repro.core.rlnc import EncodedBatch, SeededBatch

MODES = ("flip", "forge", "both")


class ByzantineChannel:
    """Corrupt each transmitted tuple independently with prob `rate`.

    Exposes the full channel protocol: ``plan_transform`` (a RowTamper
    — the engine's fused path applies, and verifies, the corruption
    without materializing the honest payload between stages) and
    ``transmit_encoded`` (the stage-wise oracle, consuming the same
    RNG stream and producing bit-identical corruption).
    """

    def __init__(self, rate: float, seed: int = 0, mode: str = "flip"):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"corruption rate {rate} outside [0, 1]")
        self.rate = float(rate)
        self.mode = mode
        self.rng = np.random.default_rng(seed)
        self.corrupted = 0      # tuples tampered with so far

    def plan_transform(self, n: int, s: int) -> RowTamper:
        """Decide this transmission's corruption pattern (one draw of
        the same RNG stream `transmit_encoded` consumes)."""
        hit = self.rng.random(n) < self.rate
        idx = np.nonzero(hit)[0]
        m = int(idx.size)
        self.corrupted += m
        # draw both seed vectors regardless of mode so the RNG stream
        # (and therefore every later round) is mode-independent
        row_seeds = self.rng.integers(0, 2**32, size=m, dtype=np.uint32)
        payload_seeds = self.rng.integers(0, 2**32, size=m,
                                          dtype=np.uint32)
        return RowTamper(
            idx=idx,
            row_seeds=row_seeds if self.mode in ("forge", "both") else None,
            payload_seeds=(payload_seeds if self.mode in ("flip", "both")
                           else None),
        )

    def transmit_encoded(self, batch, s: int
                         ) -> tuple[EncodedBatch, ChannelReport]:
        """Stage-wise oracle for the fused RowTamper path."""
        plan = self.plan_transform(batch.n, s)
        out = apply_tamper(batch, plan, s)
        dec = (out.n >= out.K
               and int(gf_rank(get_field(s), out.A)) == out.K)
        return out, ChannelReport(batch.n, out.n, dec)


def apply_tamper(batch, plan: RowTamper, s: int) -> EncodedBatch:
    """Materialize a RowTamper plan against an encoded batch.

    A SeededBatch is expanded first: a corrupted row is no longer
    derivable from any 4-byte seed, so the tampered batch is always
    materialized (exactly what a downstream receiver would see)."""
    if isinstance(batch, SeededBatch):
        batch = batch.expand(s)
    A = jnp.asarray(batch.A)
    C = jnp.asarray(batch.C)
    if plan.m:
        idx = jnp.asarray(np.asarray(plan.idx), jnp.int32)
        if plan.row_seeds is not None:
            err = seedlib.expand_rows_jit(
                jnp.asarray(plan.row_seeds, jnp.uint32), batch.K, s)
            A = A.at[idx].set(A[idx] ^ err)
        if plan.payload_seeds is not None and C.shape[1]:
            err = seedlib.expand_rows_jit(
                jnp.asarray(plan.payload_seeds, jnp.uint32),
                int(C.shape[1]), s)
            C = C.at[idx].set(C[idx] ^ err)
    return EncodedBatch(A=A, C=C)


def replayed_seed_batch(batch: SeededBatch, count: int, s: int = 8,
                        seed: int = 0) -> SeededBatch:
    """Append `count` replayed tuples to a seeded batch: each re-sends
    the 4-byte header of a random earlier tuple with a fresh garbage
    payload.  The replayed rows are exact duplicates in the row space,
    so every one of them reaches the server's basis as a *dependent*
    arrival with a mismatched payload — the precise signature
    `StreamDecoder` counts in ``inconsistent``."""
    rng = np.random.default_rng(seed)
    pick = rng.integers(0, batch.n, size=int(count))
    seeds2 = jnp.concatenate(
        [batch.seeds, batch.seeds[jnp.asarray(pick, jnp.int32)]])
    L = int(batch.C.shape[1])
    junk = rng.integers(0, 2**s, size=(int(count), L)).astype(np.uint8)
    C2 = jnp.concatenate([batch.C, jnp.asarray(junk)])
    return SeededBatch(seeds=seeds2, C=C2, K=batch.K)


def rounds_to_recovery(engine, P, key, channel, max_rounds: int = 64
                       ) -> dict:
    """Retry engine rounds against a hostile channel until a decode is
    *accepted* (rank K reached and the redundant-rank cross-check did
    not flag corruption).  The server-side policy this measures:
    discard any flagged round and re-request fresh coded tuples.

    Returns ``rounds`` (1-based count of the accepted round; equals
    ``max_rounds`` + "accepted": False when the budget ran out),
    ``flagged`` (decodes rejected by verification), ``rank_failures``
    (corruption broke invertibility outright), ``accepted``, and
    ``correct`` — whether the accepted decode actually equals P (the
    oracle's view; False here is a missed detection)."""
    flagged = rank_failures = 0
    for r in range(int(max_rounds)):
        out = engine.round(P, jax.random.fold_in(key, r), channel,
                           verify=True)
        if not out.ok:
            rank_failures += 1
            continue
        if out.verified is False:
            flagged += 1
            continue
        return {"rounds": r + 1, "flagged": flagged,
                "rank_failures": rank_failures, "accepted": True,
                "correct": bool((out.packets == P).all())}
    return {"rounds": int(max_rounds), "flagged": flagged,
            "rank_failures": rank_failures, "accepted": False,
            "correct": False}
