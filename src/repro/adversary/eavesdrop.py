"""EavesdropperView: what a passive attacker actually learns.

`core.channel.Eavesdropper` answers one question per batch (did the
intercepted matrix reach rank K?).  This view is the *stateful*
attacker: it accumulates every intercepted tuple in the same
reduced-basis state the server's :class:`repro.engine.StreamDecoder`
uses, so "what the attacker knows" is a measurable object — achieved
rank, residual entropy, and (with colluding clients seeding the basis
with identity rows) how many individual source packets have been
isolated.

The security claim this makes measurable (paper §III-A.2): under RLNC
over GF(2^s), an attacker holding e < K independent combinations can
decode *nothing* — every source packet remains exactly |GF|^(K-e)-fold
ambiguous.  The rank of the attacker's basis is therefore the whole
story, and ``residual_entropy_bits`` = (K - rank)·s·L is the entropy
of what is still hidden (L symbols per packet).

Closed-form reference: `repro.core.security.eavesdropper_leak_probability`
(validated against this view by ``benchmarks/bench_security.py``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import seeds as seedlib
from repro.engine.stream import StreamDecoder


def edge_row_slices(edges, spare_per_edge: int = 0) -> list[tuple[int, int]]:
    """Row ranges of each edge's block in the stacked coding matrix
    built by :meth:`CodingEngine.multi_edge_coding_matrix` (edge e
    contributes ``len(edges[e]) + spare_per_edge`` consecutive rows).

    >>> edge_row_slices([(0, 1), (2,)], spare_per_edge=1)
    [(0, 3), (3, 5)]
    """
    out, start = [], 0
    for ids in edges:
        stop = start + len(ids) + int(spare_per_edge)
        out.append((start, stop))
        start = stop
    return out


def tap_edges(A, edges, tapped, spare_per_edge: int = 0) -> np.ndarray:
    """The rows an attacker sitting on edge links `tapped` captures out
    of a stacked hierarchical coding matrix `A` (global coding-vector
    space).  Edge blocks have support only on their member columns, so
    capturing every row of e < E edges still spans < K columns — the
    structural form of the e < K claim."""
    rows = []
    slices = edge_row_slices(edges, spare_per_edge)
    for e in sorted(set(int(t) for t in tapped)):
        start, stop = slices[e]
        rows.append(np.asarray(A)[start:stop])
    if not rows:
        return np.zeros((0, np.asarray(A).shape[1]), np.uint8)
    return np.concatenate(rows, axis=0)


class EavesdropperView:
    """Accumulated knowledge of a passive attacker on one stream.

    Feed it whatever crosses the tapped links — materialized (m, K)
    coding rows or (m,) uint32 seed headers (the 4-byte wire format
    hides nothing: the expansion is public) — via :meth:`observe`, or
    let it flip its own per-tuple coin with :meth:`intercept`.

    `colluders` lists client indices whose plaintext packets the
    attacker already has (colluding clients know their own update):
    each contributes one identity row to the basis for free.

    >>> import jax
    >>> from repro.core.gf import get_field
    >>> f = get_field(8)
    >>> A = f.random_elements(jax.random.PRNGKey(0), (6, 4))
    >>> ev = EavesdropperView(K=4)
    >>> ev.observe(A[:3])           # 3 of 4: rank wall not reached
    3
    >>> ev.rank < 4 and not ev.full_leak
    True
    >>> ev.observe(A[3:])
    4
    >>> ev.full_leak                # >= K independent rows captured
    True
    """

    def __init__(self, K: int, s: int = 8, seed: int = 0,
                 p_intercept: float = 0.0, colluders=()):
        self.K, self.s = int(K), int(s)
        self.p = float(p_intercept)
        self.rng = np.random.default_rng(seed)
        self._dec = StreamDecoder(K=self.K, L=0, s=self.s)
        self.intercepted = 0
        self.colluders = tuple(int(i) for i in colluders)
        for i in self.colluders:
            if not 0 <= i < self.K:
                raise ValueError(f"colluder {i} outside range({self.K})")
            e_i = np.zeros((self.K,), np.uint8)
            e_i[i] = 1
            self._dec.push(e_i)

    # -- feeding ----------------------------------------------------------

    def observe(self, rows) -> int:
        """Consume captured coding rows (or seed headers); returns the
        rank afterwards."""
        rows = np.asarray(rows)
        if rows.size:
            self._dec.ingest(rows)
            self.intercepted += int(rows.shape[0])
        return self.rank

    def intercept(self, rows) -> int:
        """Per-tuple interception: each of the transmitted `rows` is
        captured independently with probability ``p_intercept`` (own
        RNG).  Returns how many were captured this call.

        Missed tuples are fed as all-zero rows — a zero row is a
        dependent arrival and leaves the basis untouched — so the
        ingest shape stays (n, K) whatever the coin flips, and the
        jitted scan compiles once instead of once per capture count."""
        rows = np.asarray(rows)
        n = int(rows.shape[0])
        got = self.rng.random(n) < self.p
        if rows.ndim == 1:       # uint32 seed headers: expansion public
            rows = np.asarray(seedlib.expand_rows_jit(
                jnp.asarray(rows, jnp.uint32), self.K, self.s))
        if n:
            self._dec.ingest(np.where(got[:, None], rows, 0))
        self.intercepted += int(got.sum())
        return int(got.sum())

    # -- what the attacker has --------------------------------------------

    @property
    def rank(self) -> int:
        """Dimension of the attacker's span (colluders included)."""
        return self._dec.rank

    @property
    def full_leak(self) -> bool:
        """rank == K: the attacker can run the same GE the server runs."""
        return self.rank == self.K

    def residual_entropy_bits(self, L: int = 1) -> float:
        """Entropy proxy of what is still hidden: each unresolved basis
        dimension is a uniformly unknown GF(2^s) row of L symbols."""
        return float((self.K - self.rank) * self.s * L)

    def sources_recovered(self) -> int:
        """Source packets the attacker has *isolated* — basis rows that
        reduced to a unit vector.  Always >= len(colluders); grows past
        it only when interception + collusion pin down further columns
        (at rank K it jumps to K: the RREF basis is the identity)."""
        B = np.asarray(self._dec.basis())
        unit = (B != 0).sum(axis=1) == 1
        diag = B[np.arange(self.K), np.arange(self.K)] == 1
        return int((unit & diag).sum())

    def report(self) -> dict:
        return {
            "intercepted": self.intercepted,
            "colluders": len(self.colluders),
            "rank": self.rank,
            "full_leak": bool(self.full_leak),
            "sources_recovered": self.sources_recovered(),
            "residual_entropy_bits": self.residual_entropy_bits(),
        }
