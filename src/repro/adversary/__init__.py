"""repro.adversary — the paper's "Secure" claim as executable models.

spec.py      — AdversarySpec: the grid axis value
               (``none`` / ``eavesdrop:p`` / ``collude:c`` /
               ``byzantine:b``) parsed in one place.
eavesdrop.py — EavesdropperView: a passive attacker's accumulated
               knowledge as reduced-basis state (achieved rank,
               residual entropy, sources recovered), plus edge-link
               capture for hierarchical cells.
byzantine.py — ByzantineChannel: active corruption as a RowTamper
               channel plan (flip / forge / both), replayed-seed
               batches for the stream path, and the rounds-to-recovery
               measurement against the engine's redundant-rank
               cross-check.

Closed forms live in `repro.core.security`; the measured counterparts
produced here are validated against them in
``benchmarks/bench_security.py`` (artifact: BENCH_security.json) and
surfaced per grid cell through the ``adversary`` axis
(`repro.grid`).  See docs/security.md for the threat model.
"""
from .byzantine import (MODES, ByzantineChannel, apply_tamper,
                        replayed_seed_batch, rounds_to_recovery)
from .eavesdrop import EavesdropperView, edge_row_slices, tap_edges
from .spec import KINDS, AdversarySpec

__all__ = [
    "AdversarySpec", "KINDS", "EavesdropperView", "edge_row_slices",
    "tap_edges", "ByzantineChannel", "MODES", "apply_tamper",
    "replayed_seed_batch", "rounds_to_recovery",
]
