"""CLI: merge and summarize Chrome trace files.

    PYTHONPATH=src python -m repro.obs TRACE_serve.json
    PYTHONPATH=src python -m repro.obs TRACE_a.json TRACE_b.json \\
        --merge TRACE_all.json --json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.report import (
    load_trace,
    markdown_summary,
    merge_events,
    summarize,
    validate_trace,
)
from repro.obs.trace import save_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="validate, merge, and summarize Chrome trace files")
    ap.add_argument("traces", nargs="+", help="TRACE_*.json files")
    ap.add_argument("--merge", metavar="OUT",
                    help="write the merged trace document to OUT")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of markdown")
    args = ap.parse_args(argv)

    lists = []
    for path in args.traces:
        events = load_trace(path)
        errors = validate_trace(events)
        if errors:
            for e in errors[:10]:
                print(f"{path}: {e}", file=sys.stderr)
            return 1
        lists.append(events)
    events = merge_events(*lists)

    if args.merge:
        save_events(events, args.merge)
        print(f"wrote {args.merge} ({len(events)} events)",
              file=sys.stderr)

    s = summarize(events)
    if args.json:
        print(json.dumps(s, indent=2))
    else:
        title = " + ".join(args.traces)
        print(markdown_summary(s, title=title), end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
