"""repro.obs — tracing, metrics, and per-stage profiling.

One timing idiom for the whole repo:

* ``with obs.timed("fl.round") as sw: ...`` — always-on stopwatch
  (replaces raw ``time.perf_counter()`` pairs); ``sw.dur_s`` after.
* ``with obs.get_tracer().span("engine.encode") as sp:
  sp.fence(out)`` — a Chrome trace span that fences device work, only
  recorded when tracing is enabled (``obs.set_tracer(obs.Tracer())``).
* ``reg = obs.MetricsRegistry(); reg.counter("dispatches").inc()`` —
  mergeable counters/gauges/histograms snapshotting to
  ``fednc-metrics-v1`` JSON.

``python -m repro.obs TRACE_serve.json`` summarizes saved traces;
see docs/observability.md.
"""
from repro.obs.metrics import (
    METRICS_SCHEMA,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exp_buckets,
    merge_snapshots,
)
from repro.obs.report import (
    load_trace,
    markdown_summary,
    merge_events,
    stage_totals,
    summarize,
    validate_trace,
)
from repro.obs.trace import (
    NULL_TRACER,
    TRACE_SCHEMA,
    NullTracer,
    Tracer,
    clock,
    device_sync,
    events_document,
    get_tracer,
    save_events,
    set_tracer,
    timed,
)

__all__ = [
    "METRICS_SCHEMA", "TRACE_SCHEMA",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "exp_buckets", "merge_snapshots",
    "load_trace", "markdown_summary", "merge_events", "stage_totals",
    "summarize", "validate_trace",
    "NULL_TRACER", "NullTracer", "Tracer", "clock", "device_sync",
    "events_document", "get_tracer", "save_events", "set_tracer",
    "timed",
]
