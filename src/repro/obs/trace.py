"""The tracing core: Chrome trace-event JSON, one timing idiom.

Every wall-clock number this repo publishes used to come from an
ad-hoc ``time.perf_counter()`` pair; this module replaces that with
two primitives sharing one clock:

* :class:`Tracer` — an *enabled* tracer records spans
  (:meth:`Tracer.span`, Chrome ``"X"`` complete events), instants
  (``"i"``) and counter samples (``"C"``) into an in-memory event
  list that saves as Chrome trace-event JSON (open it in Perfetto /
  ``chrome://tracing``).  The module-level active tracer defaults to
  :data:`NULL_TRACER`, whose ``span()`` returns a shared no-op
  context manager — no timestamps are taken, no events allocated, so
  hot paths (per-chunk kernel dispatches, per-tick server loops) pay
  essentially nothing when tracing is off.
* :func:`timed` — an always-on stopwatch for the wall numbers call
  sites need regardless of tracing (``RoundLog.wall_s``, grid cell
  walls, benchmark loops).  When the active tracer is enabled the
  same measurement also lands in the trace as a span, so enabling
  tracing never changes *what* is measured, only whether it is
  recorded.

Spans fence device work before the clock stops
(:meth:`Span.fence` -> :func:`device_sync` ->
``jax.block_until_ready``), so a traced span measures *device* time
— JAX's async dispatch otherwise returns control to Python with the
kernel still in flight and the span would under-report.

Event timestamps are epoch-anchored microseconds
(``time_ns`` offset measured once per process against
``perf_counter_ns``), so traces recorded by different processes —
e.g. ``run_grid(jobs=N)`` spawn workers — merge onto one timeline,
each process in its own pid lane.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from typing import Optional

#: schema tag recorded in the trace document's ``otherData``
TRACE_SCHEMA = "fednc-trace-v1"

# perf_counter gives the best-resolution monotonic durations; the
# offset anchors its arbitrary origin to the epoch once per process so
# per-process lanes share a timeline when merged
_EPOCH_OFFSET_NS = time.time_ns() - time.perf_counter_ns()


def clock() -> float:
    """Monotonic seconds — THE clock every obs measurement uses."""
    return time.perf_counter()


def device_sync(x):
    """Fence: block until every device computation in `x` finished.

    No-op for None and for values jax does not recognize (plain
    floats, numpy arrays), so call sites can pass whatever the block
    produced without caring about its type.  Returns `x`."""
    if x is None:
        return x
    try:
        import jax
    except ImportError:                                # pragma: no cover
        return x
    try:
        jax.block_until_ready(x)
    except (TypeError, ValueError):                    # non-pytree values
        pass
    return x


class Span:
    """One traced section: ``with tracer.span("engine.encode") as sp``.

    ``sp.fence(out)`` registers device output to block on before the
    clock stops (so the span measures device time, not dispatch time);
    ``sp.dur_s`` holds the duration after exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_pending",
                 "dur_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._pending = None
        self.dur_s = 0.0

    def fence(self, x):
        """Block on `x` (device work) just before the span closes."""
        self._pending = x
        return x

    def set(self, **args) -> "Span":
        """Attach/override span args from inside the block."""
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._pending is not None:
            device_sync(self._pending)
            self._pending = None
        t1 = time.perf_counter_ns()
        self.dur_s = (t1 - self._t0) / 1e9
        self._tracer._complete(self.name, self.cat, self._t0, t1,
                               self.args)
        return False


class _NullSpan:
    """The shared do-nothing span :data:`NULL_TRACER` hands out."""

    __slots__ = ()
    dur_s = 0.0

    def fence(self, x):
        return x

    def set(self, **args) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call is a no-op, no time is read."""

    enabled = False
    events: tuple = ()

    def span(self, name: str, cat: str = "", **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "", **args) -> None:
        pass

    def counter(self, name: str, value, cat: str = "") -> None:
        pass

    def extend(self, events) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """An enabled tracer accumulating Chrome trace events in memory.

    >>> tr = Tracer(process_name="doctest")
    >>> with tr.span("work", cat="demo", items=3):
    ...     pass
    >>> tr.instant("mark", cat="demo")
    >>> tr.counter("depth", 4)
    >>> [e["ph"] for e in tr.events if e["ph"] != "M"]
    ['X', 'i', 'C']
    """

    enabled = True

    def __init__(self, process_name: Optional[str] = None):
        self.events: list[dict] = []
        self.pid = os.getpid()
        self._tids: dict[int, int] = {}
        self._lock = threading.Lock()
        if process_name:
            self.events.append({
                "name": "process_name", "ph": "M", "pid": self.pid,
                "tid": 0, "args": {"name": str(process_name)},
            })

    # -- internals --------------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    @staticmethod
    def _ts(t_ns: int) -> float:
        return (t_ns + _EPOCH_OFFSET_NS) / 1e3       # epoch microseconds

    def _complete(self, name: str, cat: str, t0_ns: int, t1_ns: int,
                  args: dict) -> None:
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": self._ts(t0_ns), "dur": (t1_ns - t0_ns) / 1e3,
              "pid": self.pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- the emitting API -------------------------------------------------

    def span(self, name: str, cat: str = "", **args) -> Span:
        """A duration ("X") event as a context manager."""
        return Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "", **args) -> None:
        """A point-in-time ("i") event (arrivals, completions, ...)."""
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._ts(time.perf_counter_ns()),
              "pid": self.pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, value, cat: str = "") -> None:
        """A counter-track ("C") sample — Perfetto renders these as
        per-tick counter lanes (queue depth, slot occupancy, ...)."""
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": self._ts(time.perf_counter_ns()),
            "pid": self.pid, "tid": self._tid(),
            "args": {name: float(value)},
        })

    def extend(self, events) -> None:
        """Merge events recorded elsewhere (e.g. a worker process —
        they keep their own pid, so they land in their own lane)."""
        self.events.extend(events)

    # -- the document -----------------------------------------------------

    def to_document(self) -> dict:
        return events_document(self.events)

    def save(self, path) -> pathlib.Path:
        return save_events(self.events, path)


def events_document(events) -> dict:
    """Wrap an event list as a Chrome trace-event JSON document."""
    return {
        "traceEvents": list(events),
        "displayTimeUnit": "ms",
        "otherData": {"schema": TRACE_SCHEMA},
    }


def save_events(events, path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.write_text(json.dumps(events_document(events)))
    return path


# -- the active tracer ------------------------------------------------------

_active: "Tracer | NullTracer" = NULL_TRACER


def get_tracer():
    """The process-wide active tracer (NULL_TRACER unless enabled)."""
    return _active


def set_tracer(tracer):
    """Install `tracer` as the active tracer; returns it.

    ``set_tracer(NULL_TRACER)`` disables tracing again."""
    global _active
    _active = tracer
    return tracer


class Stopwatch:
    """Always-on timing: measures even when tracing is disabled, and
    additionally emits a span into `tracer` when it is enabled."""

    __slots__ = ("name", "cat", "args", "_tracer", "_pending", "_t0",
                 "dur_s")

    def __init__(self, name: str, cat: str, tracer, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self._tracer = tracer
        self._pending = None
        self.dur_s = 0.0

    def fence(self, x):
        """Block on `x` (device work) before the clock stops."""
        self._pending = x
        return x

    def set(self, **args) -> "Stopwatch":
        self.args.update(args)
        return self

    def __enter__(self) -> "Stopwatch":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._pending is not None:
            device_sync(self._pending)
            self._pending = None
        t1 = time.perf_counter_ns()
        self.dur_s = (t1 - self._t0) / 1e9
        tr = self._tracer
        if tr is not None and tr.enabled:
            tr._complete(self.name, self.cat, self._t0, t1, self.args)
        return False


_USE_ACTIVE = object()


def timed(name: str, cat: str = "", tracer=_USE_ACTIVE,
          **args) -> Stopwatch:
    """The repo-wide stopwatch idiom (replaces raw ``perf_counter``).

    >>> with timed("demo.sleep", cat="demo") as sw:
    ...     _ = sum(range(10))
    >>> sw.dur_s >= 0.0
    True
    """
    if tracer is _USE_ACTIVE:
        tracer = get_tracer()
    return Stopwatch(name, cat, tracer, args)
