"""Load / validate / merge / summarize Chrome trace-event documents.

This is the analysis half of ``repro.obs``: given one or more
``TRACE_*.json`` files (written by :meth:`repro.obs.Tracer.save`), it

* validates them as Chrome trace-event JSON (:func:`validate_trace` —
  the same rules ``scripts/check_bench.py`` enforces standalone),
* merges them onto one timeline (:func:`merge_events` — processes stay
  separated by pid lane, no timestamp rewriting needed because every
  tracer records epoch-anchored microseconds),
* and reduces them to per-stage totals/shares and counter-track stats
  (:func:`summarize`, :func:`stage_totals`) — the numbers
  ``python -m repro.obs`` and ``scripts/make_report.py --obs`` print.
"""
from __future__ import annotations

import json
import pathlib
from typing import Iterable

#: phases that carry a duration
_DUR_PHASES = {"X"}
#: metadata events are exempt from ts/pid/tid requirements
_META_PHASES = {"M"}


def load_trace(path) -> list[dict]:
    """Read a trace file; accepts both the ``{"traceEvents": [...]}``
    document form and a bare JSON event array."""
    doc = json.loads(pathlib.Path(path).read_text())
    if isinstance(doc, list):
        return doc
    if isinstance(doc, dict) and isinstance(doc.get("traceEvents"), list):
        return doc["traceEvents"]
    raise ValueError(f"{path}: not a Chrome trace-event document")


def validate_trace(events: Iterable[dict]) -> list[str]:
    """Chrome trace-event structural checks; returns error strings.

    >>> validate_trace([{"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
    ...                  "pid": 1, "tid": 0}])
    []
    >>> validate_trace([{"ph": "X"}])[0]
    "event 0: missing field 'name'"
    """
    errors = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errors.append(f"event {i}: missing field 'ph'")
            continue
        if "name" not in ev:
            errors.append(f"event {i}: missing field 'name'")
            continue
        if ph in _META_PHASES:
            continue
        for field in ("ts", "pid", "tid"):
            if not isinstance(ev.get(field), (int, float)):
                errors.append(
                    f"event {i} ({ev['name']}): non-numeric {field!r}")
        if ph in _DUR_PHASES:
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event {i} ({ev['name']}): X event needs dur >= 0")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                errors.append(
                    f"event {i} ({ev['name']}): C event needs numeric "
                    "args")
    return errors


def merge_events(*event_lists: Iterable[dict]) -> list[dict]:
    """Concatenate per-process event lists and sort by timestamp
    (metadata events first so lane names are set before use)."""
    merged: list[dict] = []
    for evs in event_lists:
        merged.extend(evs)
    merged.sort(key=lambda e: (e.get("ph") not in _META_PHASES,
                               e.get("ts", 0.0)))
    return merged


def stage_totals(events: Iterable[dict],
                 exclude: tuple = ()) -> dict:
    """Total seconds per span name — the per-stage breakdown a grid
    cell publishes.  `exclude` drops envelope spans (e.g. the
    whole-scenario span) that would double-count their children."""
    totals: dict = {}
    for ev in events:
        if ev.get("ph") in _DUR_PHASES and ev["name"] not in exclude:
            totals[ev["name"]] = (totals.get(ev["name"], 0.0)
                                  + ev.get("dur", 0.0) / 1e6)
    return {k: round(v, 9) for k, v in sorted(totals.items())}


def summarize(events: Iterable[dict]) -> dict:
    """Reduce a trace to stages / counters / instants.

    >>> s = summarize([
    ...     {"name": "enc", "ph": "X", "ts": 0, "dur": 2e6, "pid": 1,
    ...      "tid": 0},
    ...     {"name": "enc", "ph": "X", "ts": 2e6, "dur": 2e6, "pid": 1,
    ...      "tid": 0},
    ...     {"name": "q", "ph": "C", "ts": 0, "pid": 1, "tid": 0,
    ...      "args": {"q": 3.0}}])
    >>> s["stages"]["enc"]["count"], s["stages"]["enc"]["share"]
    (2, 1.0)
    >>> s["counters"]["q"]["max"]
    3.0
    """
    stages: dict = {}
    counters: dict = {}
    instants: dict = {}
    pids = set()
    for ev in events:
        ph = ev.get("ph")
        if ph in _META_PHASES:
            continue
        pids.add(ev.get("pid"))
        name = ev.get("name", "?")
        if ph in _DUR_PHASES:
            st = stages.setdefault(name, {"count": 0, "total_s": 0.0})
            st["count"] += 1
            st["total_s"] += ev.get("dur", 0.0) / 1e6
        elif ph == "C":
            for v in (ev.get("args") or {}).values():
                c = counters.setdefault(
                    name, {"n": 0, "sum": 0.0, "min": None,
                           "max": None, "last": None})
                c["n"] += 1
                c["sum"] += v
                c["min"] = v if c["min"] is None else min(c["min"], v)
                c["max"] = v if c["max"] is None else max(c["max"], v)
                c["last"] = v
        elif ph == "i":
            instants[name] = instants.get(name, 0) + 1
    grand = sum(st["total_s"] for st in stages.values())
    for st in stages.values():
        st["total_s"] = round(st["total_s"], 9)
        st["mean_s"] = round(st["total_s"] / st["count"], 9)
        st["share"] = round(st["total_s"] / grand, 6) if grand else 0.0
    for c in counters.values():
        c["mean"] = round(c["sum"] / c["n"], 6) if c["n"] else 0.0
    return {"stages": dict(sorted(stages.items())),
            "counters": dict(sorted(counters.items())),
            "instants": dict(sorted(instants.items())),
            "processes": len(pids),
            "total_span_s": round(grand, 9)}


def markdown_summary(summary: dict, title: str = "trace") -> str:
    """Render :func:`summarize` output as a markdown report."""
    lines = [f"## {title}", ""]
    lines.append(f"{summary['processes']} process lane(s), "
                 f"{summary['total_span_s']:.4f} s total span time")
    if summary["stages"]:
        lines += ["", "| stage | count | total s | mean s | share |",
                  "|---|---:|---:|---:|---:|"]
        for name, st in summary["stages"].items():
            lines.append(
                f"| `{name}` | {st['count']} | {st['total_s']:.5f} "
                f"| {st['mean_s']:.6f} | {100 * st['share']:.1f}% |")
    if summary["counters"]:
        lines += ["", "| counter | samples | min | mean | max | last |",
                  "|---|---:|---:|---:|---:|---:|"]
        for name, c in summary["counters"].items():
            lines.append(
                f"| `{name}` | {c['n']} | {c['min']:g} | {c['mean']:g} "
                f"| {c['max']:g} | {c['last']:g} |")
    if summary["instants"]:
        lines += ["", "| instant | count |", "|---|---:|"]
        for name, n in summary["instants"].items():
            lines.append(f"| `{name}` | {n} |")
    return "\n".join(lines) + "\n"
