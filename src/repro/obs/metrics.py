"""Named Counter / Gauge / Histogram with mergeable JSON snapshots.

A :class:`MetricsRegistry` is a bag of named instruments; each process
(engine, server, grid worker) keeps its own and snapshots it into a
plain-JSON document tagged ``fednc-metrics-v1``.  Snapshots from
different processes merge associatively (:func:`merge_snapshots`):
counters add, gauges pool min/max/sum/count, histograms add bucket
counts (fixed, identical bounds are required — that is what makes the
merge exact rather than approximate).

The histogram is fixed-bucket on purpose: merging two t-digest-style
sketches is approximate and order-dependent, while summing aligned
bucket counts is exact and associative, which the grid's
process-pool fan-out needs (worker snapshots arrive in completion
order).
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Optional, Sequence

#: schema tag every snapshot carries (validated by scripts/check_bench.py)
METRICS_SCHEMA = "fednc-metrics-v1"


def exp_buckets(lo: float = 1e-5, hi: float = 100.0,
                per_decade: int = 3) -> tuple:
    """Log-spaced bucket bounds covering [lo, hi] — the default for
    latency histograms (10 µs .. 100 s at 3 buckets/decade).

    >>> exp_buckets(0.001, 1.0, per_decade=1)
    (0.001, 0.01, 0.1, 1.0)
    """
    import math
    n_dec = math.log10(hi / lo)
    n = round(n_dec * per_decade)
    return tuple(round(lo * 10 ** (i / per_decade), 12)
                 for i in range(n + 1))


class Counter:
    """Monotonic count: dispatches, ticks, dropped packets.

    >>> c = Counter("demo")
    >>> c.inc(); c.inc(2); c.value
    3
    """

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Sampled level: queue depth, slot occupancy.  Tracks last /
    min / max / sum / count so merged snapshots keep a usable mean.

    >>> g = Gauge("demo")
    >>> g.set(3); g.set(7); (g.min, g.max, g.mean)
    (3.0, 7.0, 5.0)
    """

    __slots__ = ("name", "last", "min", "max", "sum", "count")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.last = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.sum = 0.0
        self.count = 0

    def set(self, value) -> None:
        v = float(value)
        self.last = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"type": self.kind, "last": self.last, "min": self.min,
                "max": self.max, "sum": self.sum, "count": self.count}


class Histogram:
    """Fixed-bucket distribution: job latencies, batch sizes.

    `bounds` are ascending upper edges; an implicit overflow bucket
    catches everything above the last bound, so ``len(counts) ==
    len(bounds) + 1`` and no observation is ever dropped.

    >>> h = Histogram("demo", bounds=(1.0, 2.0, 4.0))
    >>> for v in (0.5, 1.5, 3.0, 100.0): h.observe(v)
    >>> h.counts
    [1, 1, 1, 1]
    >>> h.percentile(0.5) <= h.percentile(0.99)
    True
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min",
                 "max")
    kind = "histogram"

    def __init__(self, name: str, bounds: Sequence[float]):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"histogram bounds must be ascending+unique: {bounds}")
        self.name = name
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value) -> None:
        v = float(value)
        self.counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> float:
        """Bucket-interpolated quantile, clamped to observed min/max
        (exact at the tails, bucket-resolution in between)."""
        if not self.count:
            return 0.0
        assert self.min is not None and self.max is not None
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = (self.bounds[i] if i < len(self.bounds)
                      else self.max)
                frac = (target - seen) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {"type": self.kind, "bounds": list(self.bounds),
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "min": self.min, "max": self.max}


class MetricsRegistry:
    """Get-or-create instrument bag with one JSON snapshot.

    >>> reg = MetricsRegistry()
    >>> reg.counter("a").inc(5)
    >>> reg.counter("a").value          # same instrument back
    5
    >>> reg.snapshot()["schema"]
    'fednc-metrics-v1'
    """

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, factory, kind: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = factory()
        elif m.kind != kind:
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, lambda: Counter(name), "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, lambda: Gauge(name), "gauge")

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        make = lambda: Histogram(name, bounds or exp_buckets())  # noqa: E731
        return self._get(name, make, "histogram")

    def snapshot(self) -> dict:
        return {"schema": METRICS_SCHEMA,
                "metrics": {name: m.snapshot()
                            for name, m in sorted(self._metrics.items())}}


def _merge_metric(name: str, a: dict, b: dict) -> dict:
    if a["type"] != b["type"]:
        raise ValueError(f"metric {name!r}: type mismatch "
                         f"{a['type']} vs {b['type']}")
    t = a["type"]
    if t == "counter":
        return {"type": t, "value": a["value"] + b["value"]}
    def _opt(f, x, y):
        vals = [v for v in (x, y) if v is not None]
        return f(vals) if vals else None
    if t == "gauge":
        return {"type": t, "last": b["last"] if b["count"] else a["last"],
                "min": _opt(min, a["min"], b["min"]),
                "max": _opt(max, a["max"], b["max"]),
                "sum": a["sum"] + b["sum"],
                "count": a["count"] + b["count"]}
    if t == "histogram":
        if list(a["bounds"]) != list(b["bounds"]):
            raise ValueError(f"histogram {name!r}: bucket bounds differ "
                             "— merge would be approximate")
        return {"type": t, "bounds": list(a["bounds"]),
                "counts": [x + y for x, y in zip(a["counts"],
                                                b["counts"],
                                                strict=True)],
                "count": a["count"] + b["count"],
                "sum": a["sum"] + b["sum"],
                "min": _opt(min, a["min"], b["min"]),
                "max": _opt(max, a["max"], b["max"])}
    raise ValueError(f"metric {name!r}: unknown type {t!r}")


def merge_snapshots(*snaps: dict) -> dict:
    """Associatively merge snapshot documents from N processes.

    >>> r1, r2 = MetricsRegistry(), MetricsRegistry()
    >>> r1.counter("n").inc(2); r2.counter("n").inc(3)
    >>> merge_snapshots(r1.snapshot(), r2.snapshot())["metrics"]["n"]["value"]
    5
    """
    merged: dict = {}
    for snap in snaps:
        if snap.get("schema") != METRICS_SCHEMA:
            raise ValueError(
                f"snapshot schema {snap.get('schema')!r} != "
                f"{METRICS_SCHEMA!r}")
        for name, m in snap["metrics"].items():
            merged[name] = (_merge_metric(name, merged[name], m)
                            if name in merged else dict(m))
    return {"schema": METRICS_SCHEMA,
            "metrics": dict(sorted(merged.items()))}
