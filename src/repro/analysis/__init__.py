"""repro.analysis — fednc-lint + abstract kernel-contract checking.

The measured-system claims (bit-exact decode, Prop. 1 ratios, serve
throughput bars) rest on invariants that tests cannot efficiently
cover: jit-safety in the hot path, one fenced timing idiom, seeded
determinism, GF dtype discipline.  This package machine-checks them:

* **fednc-lint** — AST rules FNC001–FNC005 over ``src``,
  ``benchmarks``, ``examples`` and ``scripts`` with
  ``# fednc: ignore[RULE] why`` suppressions (see
  :mod:`repro.analysis.rules`);
* **contracts** — ``jax.eval_shape`` of every registry kernel against
  the declared shape/dtype contract plus seeded/materialized sibling
  parity, zero device time (see :mod:`repro.analysis.contracts`).

CLI: ``python -m repro.analysis [--json]`` — exit 0 iff clean; the
JSON report follows the ``fednc-analysis-v1`` schema.  One-module
use:

>>> from repro import analysis
>>> src = "import time\\nt = time.time()\\n"
>>> findings, _ = analysis.analyze_source("src/repro/x.py", src)
>>> findings[0].rule, findings[0].line
('FNC001', 2)
"""
from .contracts import (DEFAULT_GRID, check_contracts,
                        check_kernel_contracts,
                        check_registry_docstring)
from .findings import (ANALYSIS_SCHEMA, Finding, Suppression,
                       apply_suppressions, parse_suppressions,
                       report_document)
from .rules import RULES, ModuleContext, Rule, register_rule, run_rules
from .runner import (DEFAULT_PATHS, analyze_file, analyze_source,
                     iter_python_files, run_analysis)

__all__ = [
    "ANALYSIS_SCHEMA", "DEFAULT_GRID", "DEFAULT_PATHS",
    "Finding", "ModuleContext", "RULES", "Rule", "Suppression",
    "analyze_file", "analyze_source", "apply_suppressions",
    "check_contracts", "check_kernel_contracts",
    "check_registry_docstring", "iter_python_files",
    "parse_suppressions", "register_rule", "report_document",
    "run_analysis", "run_rules",
]
