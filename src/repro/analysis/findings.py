"""Findings, suppressions, and the ``fednc-analysis-v1`` report.

A :class:`Finding` is one rule hit anchored to ``file:line``.  Call
sites silence a *justified* exception with an inline marker on the
flagged line::

    t0 = time.perf_counter()   # fednc: ignore[FNC001] anchoring epoch offset

The marker must name the rule id (several: ``ignore[FNC001,FNC002]``)
and SHOULD carry a one-line justification after the bracket; the
report keeps every suppression it honored, so "lints clean" is always
auditable — an empty baseline means zero findings *and* every ignore
is visible in the JSON artifact.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

#: schema tag stamped into the JSON report document
ANALYSIS_SCHEMA = "fednc-analysis-v1"

_IGNORE_RE = re.compile(
    r"#\s*fednc:\s*ignore\[([A-Z0-9,\s]+)\]\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at ``file:line``.

    >>> f = Finding("src/x.py", 3, 0, "FNC001", "error", "raw clock")
    >>> f.location
    'src/x.py:3'
    """

    file: str
    line: int
    col: int
    rule: str
    severity: str          # "error" | "warning"
    message: str

    @property
    def location(self) -> str:
        return f"{self.file}:{self.line}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")


@dataclasses.dataclass(frozen=True)
class Suppression:
    """An honored inline ``# fednc: ignore[RULE]`` marker."""

    file: str
    line: int
    rule: str
    justification: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def parse_suppressions(source: str) -> dict[int, tuple[set[str], str]]:
    """``{line_number: (rule_ids, justification)}`` for a source text.

    >>> sups = parse_suppressions(
    ...     "x = 1\\ny = 2  # fednc: ignore[FNC001] epoch anchor\\n")
    >>> sups[2]
    ({'FNC001'}, 'epoch anchor')
    """
    out: dict[int, tuple[set[str], str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = (rules, m.group(2).strip())
    return out


def apply_suppressions(
    findings: Iterable[Finding],
    suppressions: dict[int, tuple[set[str], str]],
) -> tuple[list[Finding], list[Suppression]]:
    """Split raw findings into (kept, suppressed-and-honored).

    A marker suppresses a finding only when it sits on the finding's
    own line and names the finding's rule id.
    """
    kept: list[Finding] = []
    honored: list[Suppression] = []
    for f in findings:
        entry = suppressions.get(f.line)
        if entry is not None and f.rule in entry[0]:
            honored.append(Suppression(f.file, f.line, f.rule, entry[1]))
        else:
            kept.append(f)
    return kept, honored


def report_document(*, root: str, paths: list[str], files: int,
                    findings: list[Finding],
                    suppressed: list[Suppression],
                    contracts: dict) -> dict:
    """Assemble the ``fednc-analysis-v1`` JSON document."""
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "schema": ANALYSIS_SCHEMA,
        "root": root,
        "paths": paths,
        "files_scanned": files,
        "findings": [f.to_json() for f in findings],
        "suppressed": [s.to_json() for s in suppressed],
        "counts_by_rule": counts,
        "contracts": contracts,
        "ok": not findings and not contracts.get("violations"),
    }
