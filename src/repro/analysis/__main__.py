"""CLI: ``python -m repro.analysis [--json PATH] [--root DIR]``.

Exit 0 iff the repo lints clean (inline-justified suppressions
excluded) AND every kernel in the engine registry passes its abstract
contract.  ``--json`` additionally writes the ``fednc-analysis-v1``
report (CI uploads it as an artifact beside the BENCH_/GRID_ files).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from .findings import Finding
from .runner import DEFAULT_PATHS, run_analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fednc-lint + kernel-contract checker")
    ap.add_argument("--root", default=".",
                    help="repo root to scan (default: cwd)")
    ap.add_argument("--paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="roots to lint, relative to --root")
    ap.add_argument("--json", nargs="?", const="ANALYSIS_report.json",
                    default=None, metavar="PATH",
                    help="write the fednc-analysis-v1 report "
                         "(default path: ANALYSIS_report.json)")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the kernel-contract pass (lint only; "
                         "avoids importing jax)")
    args = ap.parse_args(argv)

    report = run_analysis(args.root, args.paths,
                          contracts=not args.no_contracts)

    for f in report["findings"]:
        print(Finding(**f).render(), file=sys.stderr)
    n_sup = len(report["suppressed"])
    if args.json:
        path = pathlib.Path(args.json)
        path.write_text(json.dumps(report, indent=2))
        print(f"analysis: wrote {path}")
    if report["ok"]:
        print(f"analysis: OK ({report['files_scanned']} files, "
              f"{report['contracts']['points_checked']} contract "
              f"points, {n_sup} justified suppression(s))")
        return 0
    print(f"analysis: FAIL ({len(report['findings'])} finding(s))",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
