"""fednc-lint: AST rules codifying the repo's hard-won invariants.

Each rule is a function over a :class:`ModuleContext` registered under
a stable id.  The ids are part of the repo's contract — suppressions
(``# fednc: ignore[FNC002] why``), the JSON report, and the docs all
refer to them:

``FNC001 raw-clock``
    Any ``time.perf_counter()`` / ``time.time()`` family call outside
    ``repro/obs``.  All wall timing flows through ``obs.timed`` /
    ``obs.clock`` so published numbers share one fenced idiom.
``FNC002 unfenced-timing``
    A ``with obs.timed(...)`` / ``tracer.span(...)`` region that
    dispatches jax work but never fences (``sw.fence`` /
    ``obs.device_sync`` / ``jax.block_until_ready``) before the clock
    stops — it measures Python dispatch, not device time.
``FNC003 tracer-leak``
    Host conversions (``float()`` / ``int()`` / ``bool()`` /
    ``.item()`` / ``np.asarray``) or Python ``if``/``while`` on traced
    values inside functions reachable from ``@jax.jit`` or
    ``pl.pallas_call`` — a concretization error waiting to fire, or a
    silent recompile per call.
``FNC004 unseeded-rng``
    Global-state ``np.random.*`` / stdlib ``random.*`` draws in the
    determinism-critical paths (``sim``/``grid``/``serve``/``engine``)
    instead of an explicitly seeded ``np.random.default_rng``.
``FNC005 dtype-discipline``
    GF symbol buffers leaving uint8 (or packed lanes leaving int32)
    inside the GF kernel modules — field arithmetic on a promoted
    dtype is silently wrong, not slow.

Downstream projects add rules with :func:`register_rule`; the runner
applies every registered rule to every in-scope module.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Callable, Dict, Iterator, Optional

from .findings import Finding

__all__ = [
    "ModuleContext", "Rule", "RULES", "register_rule", "run_rules",
]


@dataclasses.dataclass
class ModuleContext:
    """One parsed module as seen by the rules."""

    rel: str                 # repo-relative posix path ("src/repro/...")
    source: str
    tree: ast.Module
    path: Optional[pathlib.Path] = None

    @classmethod
    def from_source(cls, rel: str, source: str,
                    path: Optional[pathlib.Path] = None
                    ) -> "ModuleContext":
        return cls(rel=rel, source=source,
                   tree=ast.parse(source, filename=rel), path=path)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    severity: str
    doc: str
    fn: Callable[[ModuleContext], Iterator[Finding]]


RULES: Dict[str, Rule] = {}


def register_rule(id: str, name: str, severity: str = "error",
                  doc: str = "", *, overwrite: bool = False):
    """Decorator: register ``fn(ctx) -> iterator of Finding``."""
    def deco(fn):
        if id in RULES and not overwrite:
            raise ValueError(f"rule {id!r} already registered")
        RULES[id] = Rule(id, name, severity, doc or (fn.__doc__ or ""),
                         fn)
        return fn
    return deco


def run_rules(ctx: ModuleContext,
              rules: Optional[Dict[str, Rule]] = None) -> list[Finding]:
    """Apply every rule to one module; returns raw (unsuppressed)
    findings sorted by line."""
    out: list[Finding] = []
    for rule in (rules or RULES).values():
        out.extend(rule.fn(ctx))
    return sorted(out, key=lambda f: (f.line, f.col, f.rule))


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_map(tree: ast.Module) -> dict[str, str]:
    """Local binding -> absolute dotted target for module imports.

    ``import numpy as np`` -> {'np': 'numpy'};
    ``from jax import random`` -> {'random': 'jax.random'};
    ``from time import perf_counter as pc`` -> {'pc': 'time.perf_counter'}.
    """
    binds: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                binds[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else
                    alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for alias in node.names:
                binds[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return binds


def resolve_call(func: ast.AST, binds: dict[str, str]) -> Optional[str]:
    """Absolute dotted name of a call target, through the import map."""
    name = dotted(func)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    base = binds.get(head, head)
    return f"{base}.{rest}" if rest else base


# ---------------------------------------------------------------------------
# FNC001 raw-clock
# ---------------------------------------------------------------------------

_CLOCK_FNS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
}

#: paths exempt from FNC001 — the one module allowed to own the clock
_OBS_PREFIX = "src/repro/obs/"


@register_rule("FNC001", "raw-clock", "error",
               "wall timing must flow through obs.timed / obs.clock")
def rule_raw_clock(ctx: ModuleContext) -> Iterator[Finding]:
    if ctx.rel.startswith(_OBS_PREFIX):
        return
    binds = import_map(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = resolve_call(node.func, binds)
        if target in _CLOCK_FNS:
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, "FNC001",
                "error",
                f"raw clock call {target}() — use repro.obs.timed "
                f"(always-on stopwatch) or obs.clock() so the "
                f"measurement shares the repo-wide fenced idiom")


# ---------------------------------------------------------------------------
# FNC002 unfenced-timing
# ---------------------------------------------------------------------------

#: attribute roots whose calls dispatch device work under jax
_DISPATCH_ROOTS = {"jnp", "jax", "lax"}

#: repo hot-path entry points that dispatch jax work when called as
#: methods/functions inside a timed region (engine / stream / serve /
#: federation APIs)
_DISPATCH_CALLS = {
    "encode", "encode_seeded", "decode", "round", "multi_edge_round",
    "recode", "recode_with", "ingest", "ingest_seeded", "push",
    "tick", "drain", "train", "aggregate", "fednc_round",
    "fedavg_round", "gf_matmul",
}

_FENCE_CALLS = {"fence", "device_sync", "block_until_ready"}

#: jax.* calls that fence rather than dispatch
_SYNC_TARGETS = {"jax.block_until_ready", "jax.device_get"}


def _timed_withitem(item: ast.withitem) -> bool:
    call = item.context_expr
    if not isinstance(call, ast.Call):
        return False
    name = dotted(call.func)
    if name is None:
        return False
    leaf = name.rsplit(".", 1)[-1]
    return leaf in ("timed", "span")


def _is_dispatch(call: ast.Call) -> bool:
    name = dotted(call.func)
    if name is None:
        return False
    if name in _SYNC_TARGETS:
        return False
    root, _, _ = name.partition(".")
    if root in _DISPATCH_ROOTS:
        return True
    return name.rsplit(".", 1)[-1] in _DISPATCH_CALLS


def _is_fence(call: ast.Call) -> bool:
    name = dotted(call.func)
    return (name is not None
            and name.rsplit(".", 1)[-1] in _FENCE_CALLS)


@register_rule("FNC002", "unfenced-timing", "warning",
               "timed regions that dispatch jax work must fence "
               "before the clock stops")
def rule_unfenced_timing(ctx: ModuleContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        if not any(_timed_withitem(i) for i in node.items):
            continue
        dispatches = False
        fences = False
        for sub in ast.walk(ast.Module(body=node.body,
                                       type_ignores=[])):
            if isinstance(sub, ast.Call):
                if _is_fence(sub):
                    fences = True
                elif _is_dispatch(sub):
                    dispatches = True
        if dispatches and not fences:
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, "FNC002",
                "warning",
                "timed region dispatches jax work but never fences "
                "(sw.fence(out) / obs.device_sync / "
                "jax.block_until_ready) before the clock stops — "
                "jax dispatch is async, so this measures dispatch "
                "time, not device time")


# ---------------------------------------------------------------------------
# FNC003 tracer-leak
# ---------------------------------------------------------------------------

_HOST_CASTS = {"float", "int", "bool"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}
_NP_HOST_FNS = {"numpy.asarray", "numpy.array"}


def _decorator_jit_static(dec: ast.AST) -> Optional[tuple[str, ...]]:
    """static_argnames if `dec` is a jit decorator, else None."""
    name = dotted(dec)
    if name is not None and name.rsplit(".", 1)[-1] == "jit":
        return ()
    if isinstance(dec, ast.Call):
        cname = dotted(dec.func)
        if cname is None:
            return None
        leaf = cname.rsplit(".", 1)[-1]
        if leaf == "jit":                       # @jax.jit(...) form
            return _static_argnames_kwarg(dec)
        if leaf == "partial" and dec.args:      # @partial(jax.jit, ...)
            inner = dotted(dec.args[0])
            if inner and inner.rsplit(".", 1)[-1] == "jit":
                return _static_argnames_kwarg(dec)
    return None


def _static_argnames_kwarg(call: ast.Call) -> tuple[str, ...]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            names: list[str] = []
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    names.append(elt.value)
            return tuple(names)
    return ()


def _collect_functions(tree: ast.Module) -> dict[str, ast.AST]:
    funcs: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs.setdefault(node.name, node)
    return funcs


def _jit_roots(tree: ast.Module,
               funcs: dict[str, ast.AST]) -> dict[str, tuple[str, ...]]:
    """{function name: static param names} for every jit/pallas root."""
    roots: dict[str, tuple[str, ...]] = {}
    for name, node in funcs.items():
        for dec in getattr(node, "decorator_list", []):
            static = _decorator_jit_static(dec)
            if static is not None:
                roots[name] = static
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        cname = dotted(node.func)
        if cname is None:
            continue
        leaf = cname.rsplit(".", 1)[-1]
        if leaf == "jit":
            # jax.jit(f) / jax.jit(jax.vmap(f)): f becomes a root
            static = _static_argnames_kwarg(node)
            for arg in node.args:
                for ref in ast.walk(arg):
                    if isinstance(ref, ast.Name) and ref.id in funcs:
                        roots.setdefault(ref.id, static)
        elif leaf == "pallas_call" and node.args:
            # the kernel body: keyword-only params are partial-bound
            # compile-time constants, positional params are refs
            for ref in ast.walk(node.args[0]):
                if isinstance(ref, ast.Name) and ref.id in funcs:
                    fn = funcs[ref.id]
                    kwonly = tuple(a.arg for a in fn.args.kwonlyargs)
                    roots.setdefault(ref.id, kwonly)
    return roots


def _reachable(funcs: dict[str, ast.AST],
               roots: dict[str, tuple[str, ...]]) -> set[str]:
    """Names reachable from the roots via same-module references."""
    seen = set(roots)
    frontier = list(roots)
    while frontier:
        fn = funcs[frontier.pop()]
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and node.id in funcs \
                    and node.id not in seen:
                seen.add(node.id)
                frontier.append(node.id)
    return seen


def _expr_tainted(expr: ast.AST, tainted: set[str]) -> bool:
    """True if `expr` reads a traced value.

    ``.shape`` / ``.ndim`` / ``.size`` / ``.dtype`` subtrees are
    trace-static regardless of what they are read from, so Python
    control flow on them is jit-safe and never flagged."""
    if isinstance(expr, ast.Attribute) and expr.attr in _STATIC_ATTRS:
        return False
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    return any(_expr_tainted(child, tainted)
               for child in ast.iter_child_nodes(expr))


def _check_function(ctx: ModuleContext, fn: ast.AST,
                    static: tuple[str, ...],
                    binds: dict[str, str]) -> Iterator[Finding]:
    args = fn.args
    params = [a.arg for a in (args.posonlyargs + args.args
                              + args.kwonlyargs)]
    if args.vararg:
        params.append(args.vararg.arg)
    if args.kwarg:
        params.append(args.kwarg.arg)
    tainted = {p for p in params if p not in static}

    # forward taint through simple assignments, two passes for
    # use-before-def within loops
    body_nodes = list(ast.walk(fn))
    for _ in range(2):
        for node in body_nodes:
            if isinstance(node, (ast.Assign, ast.AugAssign,
                                 ast.AnnAssign)):
                value = getattr(node, "value", None)
                if value is None:
                    continue
                targets = (node.targets
                           if isinstance(node, ast.Assign)
                           else [node.target])
                if _expr_tainted(value, tainted):
                    for t in targets:
                        for leaf in ast.walk(t):
                            if isinstance(leaf, ast.Name):
                                tainted.add(leaf.id)

    for node in body_nodes:
        if isinstance(node, (ast.If, ast.While)) \
                and _expr_tainted(node.test, tainted):
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, "FNC003",
                "error",
                f"Python {'if' if isinstance(node, ast.If) else 'while'}"
                f" on a traced value inside jit-reachable "
                f"'{fn.name}' — use lax.cond/lax.while_loop or hoist "
                f"the value to a static argument")
        elif isinstance(node, ast.Call):
            cname = dotted(node.func)
            if cname is None:
                continue
            resolved = resolve_call(node.func, binds)
            leaf = cname.rsplit(".", 1)[-1]
            if cname in _HOST_CASTS and node.args \
                    and any(_expr_tainted(a, tainted)
                            for a in node.args):
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, "FNC003",
                    "error",
                    f"host conversion {cname}() of a traced value "
                    f"inside jit-reachable '{fn.name}' — forces a "
                    f"device sync / concretization error under trace")
            elif leaf == "item" and isinstance(node.func, ast.Attribute) \
                    and _expr_tainted(node.func.value, tainted):
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, "FNC003",
                    "error",
                    f".item() on a traced value inside jit-reachable "
                    f"'{fn.name}'")
            elif resolved in _NP_HOST_FNS and node.args \
                    and any(_expr_tainted(a, tainted)
                            for a in node.args):
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, "FNC003",
                    "error",
                    f"{resolved}() materializes a traced value on "
                    f"host inside jit-reachable '{fn.name}' — use "
                    f"jnp.asarray, or move the conversion outside "
                    f"the jitted region")


@register_rule("FNC003", "tracer-leak", "error",
               "host conversions / Python control flow on traced "
               "values inside jit-reachable functions")
def rule_tracer_leak(ctx: ModuleContext) -> Iterator[Finding]:
    funcs = _collect_functions(ctx.tree)
    roots = _jit_roots(ctx.tree, funcs)
    if not roots:
        return
    binds = import_map(ctx.tree)
    for name in sorted(_reachable(funcs, roots)):
        static = roots.get(name, ())
        yield from _check_function(ctx, funcs[name], static, binds)


# ---------------------------------------------------------------------------
# FNC004 unseeded-rng
# ---------------------------------------------------------------------------

#: the determinism-critical package paths
_RNG_SCOPES = ("src/repro/sim/", "src/repro/grid/", "src/repro/serve/",
               "src/repro/engine/")

#: constructors of explicitly seeded generators — the sanctioned API
_SEEDED_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "SFC64", "BitGenerator"}


@register_rule("FNC004", "unseeded-rng", "error",
               "global-state RNG in determinism-critical paths")
def rule_unseeded_rng(ctx: ModuleContext) -> Iterator[Finding]:
    if not ctx.rel.startswith(_RNG_SCOPES):
        return
    binds = import_map(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = resolve_call(node.func, binds)
        if target is None:
            continue
        if target.startswith("numpy.random."):
            leaf = target.rsplit(".", 1)[-1]
            if leaf not in _SEEDED_CTORS:
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, "FNC004",
                    "error",
                    f"global-state numpy RNG {target}() — every draw "
                    f"in {ctx.rel.split('/')[2]} must flow from an "
                    f"explicitly seeded np.random.default_rng(seed)")
        elif target.startswith("random.") \
                and target.count(".") == 1 \
                and target.rsplit(".", 1)[-1] != "Random":
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, "FNC004",
                "error",
                f"global-state stdlib RNG {target}() — use an "
                f"explicitly seeded np.random.default_rng(seed) "
                f"(or random.Random(seed))")


# ---------------------------------------------------------------------------
# FNC005 dtype-discipline
# ---------------------------------------------------------------------------

#: dtypes GF symbol / packed-lane buffers are allowed to take
_GF_DTYPES = {"uint8", "int32", "uint32", "bool_"}

#: positional index of the dtype argument for known constructors
_DTYPE_POS = {"zeros": 1, "ones": 1, "empty": 1, "asarray": 1,
              "array": 1, "full": 2, "ShapeDtypeStruct": 1,
              "bitcast_convert_type": 1}


def _gf_kernel_module(rel: str) -> bool:
    if not rel.startswith("src/repro/kernels/"):
        return False
    base = rel.rsplit("/", 1)[-1]
    return base.startswith("gf") or base == "ref.py"


def _dtype_name(node: ast.AST,
                consts: dict[str, str]) -> Optional[str]:
    """The dtype leaf name of an expression, if recognizable."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    name = dotted(node)
    if name is None:
        return None
    leaf = name.rsplit(".", 1)[-1]
    if leaf == "dtype":          # mirror casts (.astype(ref.dtype))
        return None
    return consts.get(leaf, leaf) if "." not in name else leaf


def _module_dtype_consts(tree: ast.Module) -> dict[str, str]:
    """Module-level NAME = jnp.<dtype> constants (e.g. _COMPUTE_DTYPE)."""
    consts: dict[str, str] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            value = dotted(node.value)
            if value is not None:
                consts[node.targets[0].id] = value.rsplit(".", 1)[-1]
    return consts


@register_rule("FNC005", "dtype-discipline", "error",
               "GF buffers must stay uint8 / packed lanes int32 "
               "inside the GF kernel modules")
def rule_dtype_discipline(ctx: ModuleContext) -> Iterator[Finding]:
    if not _gf_kernel_module(ctx.rel):
        return
    consts = _module_dtype_consts(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        dtype_exprs: list[ast.AST] = []
        cname = dotted(node.func)
        leaf = cname.rsplit(".", 1)[-1] if cname else ""
        if leaf == "astype" and node.args:
            dtype_exprs.append(node.args[0])
        pos = _DTYPE_POS.get(leaf)
        if pos is not None and len(node.args) > pos:
            dtype_exprs.append(node.args[pos])
        for kw in node.keywords:
            if kw.arg == "dtype":
                dtype_exprs.append(kw.value)
        for expr in dtype_exprs:
            dname = _dtype_name(expr, consts)
            if dname is None:
                continue
            if dname not in _GF_DTYPES:
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, "FNC005",
                    "error",
                    f"GF buffer cast to {dname!r} in a GF kernel "
                    f"module — symbols must stay uint8 and packed "
                    f"lanes int32; field arithmetic on a promoted "
                    f"dtype is silently wrong")
