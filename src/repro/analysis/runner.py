"""Repo walking + the one-call entry points the CLI and tests share.

``analyze_source`` lints one module text (how the test fixtures and
docs examples drive individual rules); ``analyze_file`` wraps it for
a path on disk; ``run_analysis`` walks the repo's code roots
(``src``, ``benchmarks``, ``examples``, ``scripts``), applies every
registered rule, runs the kernel-contract pass, and assembles the
``fednc-analysis-v1`` report.
"""
from __future__ import annotations

import pathlib
from typing import Optional, Sequence

from .findings import (Finding, Suppression, apply_suppressions,
                       parse_suppressions, report_document)
from .rules import RULES, ModuleContext, Rule, run_rules

#: repo-relative roots the lint half scans by default — tests stay
#: out (fixtures deliberately violate rules), artifacts/docs are not
#: Python
DEFAULT_PATHS = ("src", "benchmarks", "examples", "scripts")


def analyze_source(rel: str, source: str,
                   rules: Optional[dict[str, Rule]] = None
                   ) -> tuple[list[Finding], list[Suppression]]:
    """Lint one module given as text; returns (findings, suppressed).

    ``rel`` is the repo-relative posix path the rules use for scoping
    (e.g. FNC004 only applies under ``src/repro/sim`` etc.), so
    fixtures can opt into any scope:

    >>> bad = "import time\\nt0 = time.perf_counter()\\n"
    >>> fs, _ = analyze_source("src/repro/sim/x.py", bad)
    >>> [f.rule for f in fs]
    ['FNC001']
    """
    ctx = ModuleContext.from_source(rel, source)
    raw = run_rules(ctx, rules)
    return apply_suppressions(raw, parse_suppressions(source))


def analyze_file(path: pathlib.Path, root: pathlib.Path,
                 rules: Optional[dict[str, Rule]] = None
                 ) -> tuple[list[Finding], list[Suppression]]:
    rel = path.relative_to(root).as_posix()
    return analyze_source(rel, path.read_text(), rules)


def iter_python_files(root: pathlib.Path,
                      paths: Sequence[str] = DEFAULT_PATHS
                      ) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for sub in paths:
        base = root / sub
        if not base.exists():
            continue
        files.extend(sorted(base.rglob("*.py")))
    return files


def run_analysis(root, paths: Sequence[str] = DEFAULT_PATHS, *,
                 contracts: bool = True,
                 rules: Optional[dict[str, Rule]] = None) -> dict:
    """Lint + contract-check the repo; returns the report document.

    ``report["ok"]`` is the single gate bit: True iff zero lint
    findings (inline-justified suppressions excluded — but recorded)
    and zero contract violations.
    """
    root = pathlib.Path(root).resolve()
    findings: list[Finding] = []
    suppressed: list[Suppression] = []
    files = iter_python_files(root, paths)
    for path in files:
        f, s = analyze_file(path, root, rules)
        findings.extend(f)
        suppressed.extend(s)

    if contracts:
        from .contracts import check_contracts
        violations, summary = check_contracts()
        findings.extend(violations)
    else:
        summary = {"kernels": [], "points_checked": 0,
                   "violations": []}

    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return report_document(
        root=str(root), paths=list(paths), files=len(files),
        findings=findings, suppressed=suppressed, contracts=summary)
