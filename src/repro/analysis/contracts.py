"""analysis.contracts — abstract kernel-contract checking.

Every GF kernel in ``repro.engine.registry`` promises the same
contract: materialized kernels map ``(A (n,K) uint8, P (K,L) uint8)``
to ``(n,L) uint8``; seeded kernels map ``(seeds (n,) uint32, P)`` to
the same output.  PR 5 found (and fixed by hand) one registry/docs
drift; this module checks the whole registry *statically* on every
fast-tier run:

* each kernel is ``jax.eval_shape``-d over a representative
  ``(n, K, L, s)`` grid — abstract interpretation only, **zero device
  time**, so a kernel whose output shape or dtype drifts (or that
  crashes under trace on a packed-boundary L) is caught without
  running a single kernel;
* every ``*_seeded`` kernel must have its materialized sibling
  registered (and vice-versa mapping must round-trip through
  ``seeded_kernel_name`` / ``materialized_kernel_name``), and both
  siblings must eval to identical output structure at every grid
  point — the bit-exactness oracle's *precondition*;
* the registry module docstring's kernel table must list exactly the
  registered names (the drift PR 5 fixed by hand, pinned).

Violations come back as :class:`~repro.analysis.findings.Finding`
rows under dedicated rule ids so the CLI/report treats them uniformly
with the lint half:

``CTR001`` eval-shape contract violation (shape/dtype/trace error)
``CTR002`` seeded/materialized sibling mismatch
``CTR003`` registry docstring drift
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

from .findings import Finding

#: representative (n, K, L, s) grid: generic point, packed-boundary L
#: (non-multiple of the 4-symbol lane), exactly-one-tile L, tile+1
#: padding path, L=0 early-out, and a sub-byte field
DEFAULT_GRID: tuple[tuple[int, int, int, int], ...] = (
    (3, 4, 17, 8),
    (5, 8, 2048, 8),
    (2, 6, 2049, 8),
    (4, 3, 0, 8),
    (3, 5, 33, 4),
)

_REGISTRY_FILE = "src/repro/engine/registry.py"


def _contract_points(seeded: bool, grid) -> list[tuple]:
    """[(args, kwargs, n, K, L, s)] eval_shape inputs per grid point."""
    import jax
    import jax.numpy as jnp

    points = []
    for (n, K, L, s) in grid:
        P = jax.ShapeDtypeStruct((K, L), jnp.uint8)
        if seeded:
            first = jax.ShapeDtypeStruct((n,), jnp.uint32)
        else:
            first = jax.ShapeDtypeStruct((n, K), jnp.uint8)
        points.append(((first, P), {"s": s}, n, K, L, s))
    return points


def _eval_shape(fn, args, kwargs) -> tuple[Optional[object], str]:
    import functools

    import jax

    # kwargs (the static `s`) must stay Python values — eval_shape
    # abstracts every argument it receives, so bind them first
    try:
        return jax.eval_shape(functools.partial(fn, **kwargs),
                              *args), ""
    except Exception as e:                        # noqa: BLE001
        return None, f"{type(e).__name__}: {e}"


def check_kernel_contracts(grid: Sequence[tuple] = DEFAULT_GRID,
                           kernels: Optional[Sequence[str]] = None
                           ) -> tuple[list[Finding], dict]:
    """eval_shape every registry kernel against the declared contract.

    Returns ``(violations, summary)`` where ``summary`` is the
    ``contracts`` block of the ``fednc-analysis-v1`` report.  With no
    violations the summary records which kernels and how many grid
    points were checked — the fast tier asserts on it, so registry
    drift cannot land silently.
    """
    import jax.numpy as jnp

    from repro.engine import registry

    names = list(kernels if kernels is not None
                 else (n for n in registry.available_kernels()
                       if n not in registry._ALIASES))
    violations: list[Finding] = []
    checked = 0
    shapes: dict[str, list] = {}

    for name in names:
        seeded = registry.is_seeded_kernel(name)
        try:
            _, fn = registry.resolve_kernel(name)
        except ValueError as e:
            violations.append(Finding(
                _REGISTRY_FILE, 1, 0, "CTR001", "error",
                f"kernel {name!r}: {e}"))
            continue
        shapes[name] = []
        for args, kwargs, n, K, L, s in _contract_points(seeded, grid):
            point = f"(n={n}, K={K}, L={L}, s={s})"
            out, err = _eval_shape(fn, args, kwargs)
            checked += 1
            if out is None:
                violations.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR001", "error",
                    f"kernel {name!r} failed abstract evaluation at "
                    f"{point}: {err}"))
                shapes[name].append(None)
                continue
            shapes[name].append((tuple(out.shape), str(out.dtype)))
            if tuple(out.shape) != (n, L):
                violations.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR001", "error",
                    f"kernel {name!r} output shape {tuple(out.shape)} "
                    f"!= contract (n, L) = {(n, L)} at {point}"))
            if out.dtype != jnp.uint8:
                violations.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR001", "error",
                    f"kernel {name!r} output dtype {out.dtype} != "
                    f"contract uint8 at {point}"))

    violations.extend(_check_siblings(names, shapes))
    summary = {
        "kernels": sorted(n for n in shapes),
        "grid": [list(p) for p in grid],
        "points_checked": checked,
        "violations": [v.to_json() for v in violations],
    }
    return violations, summary


def _check_siblings(names: Sequence[str],
                    shapes: dict[str, list]) -> list[Finding]:
    """Seeded/materialized family consistency across the registry."""
    from repro.engine import registry

    out: list[Finding] = []
    for name in names:
        if registry.is_seeded_kernel(name):
            if not name.endswith(registry.SEEDED_SUFFIX):
                out.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR002", "error",
                    f"seeded kernel {name!r} must carry the "
                    f"'{registry.SEEDED_SUFFIX}' name suffix — the "
                    f"engine's structural dispatch and the sibling "
                    f"mapping both key on it"))
                continue
            base = name[: -len(registry.SEEDED_SUFFIX)]
            mat = registry.materialized_kernel_name(name)
            if base not in names:
                out.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR002", "error",
                    f"seeded kernel {name!r} has no materialized "
                    f"sibling {base!r} in the registry — the "
                    f"bit-exactness oracle (seeded output == "
                    f"materialized output on expand_rows) has "
                    f"nothing to check against"))
            elif registry.seeded_kernel_name(mat) != name:
                out.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR002", "error",
                    f"sibling mapping does not round-trip: "
                    f"materialized({name!r}) = {mat!r} but "
                    f"seeded({mat!r}) = "
                    f"{registry.seeded_kernel_name(mat)!r}"))
            elif shapes.get(name) and shapes.get(mat) \
                    and shapes[name] != shapes[mat]:
                out.append(Finding(
                    _REGISTRY_FILE, 1, 0, "CTR002", "error",
                    f"siblings {name!r} / {mat!r} disagree on "
                    f"abstract output over the contract grid: "
                    f"{shapes[name]} != {shapes[mat]}"))
        elif name.endswith(registry.SEEDED_SUFFIX):
            out.append(Finding(
                _REGISTRY_FILE, 1, 0, "CTR002", "error",
                f"kernel {name!r} carries the seeded name suffix but "
                f"was registered with seeded=False"))
    return out


_TABLE_NAME_RE = re.compile(r"``([\w]+)``")


def check_registry_docstring() -> list[Finding]:
    """The registry module docstring's kernel table == the registry.

    The table between the first and last ``====`` rules in
    ``repro.engine.registry.__doc__`` is the source-of-truth listing
    PR 5 once found stale; every registered name (and no other) must
    appear there in double backquotes.
    """
    from repro.engine import registry

    doc = registry.__doc__ or ""
    m = re.search(r"^=+ +=+$(.*?)^=+ +=+$", doc,
                  re.MULTILINE | re.DOTALL)
    if not m:
        return [Finding(_REGISTRY_FILE, 1, 0, "CTR003", "error",
                        "registry docstring has no kernel table "
                        "(==== delimited)")]
    documented = set(_TABLE_NAME_RE.findall(m.group(1)))
    live = set(registry.available_kernels())
    out: list[Finding] = []
    for missing in sorted(live - documented):
        out.append(Finding(
            _REGISTRY_FILE, 1, 0, "CTR003", "error",
            f"kernel {missing!r} is registered but missing from the "
            f"registry docstring table"))
    for stale in sorted(documented - live):
        out.append(Finding(
            _REGISTRY_FILE, 1, 0, "CTR003", "error",
            f"registry docstring table lists {stale!r} which is not "
            f"a registered kernel"))
    return out


def check_contracts(grid: Sequence[tuple] = DEFAULT_GRID
                    ) -> tuple[list[Finding], dict]:
    """The full static contract pass: eval_shape grid + siblings +
    docstring.  Returns ``(violations, report_summary)``."""
    violations, summary = check_kernel_contracts(grid)
    doc = check_registry_docstring()
    violations = violations + doc
    summary["violations"] = [v.to_json() for v in violations]
    return violations, summary
