"""Serving driver: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --reduced --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as tf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg)

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.frontend:
        memory = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)

    t0 = time.time()
    logits, cache = tf.prefill(params, prompts, cfg,
                               cache_len=S + args.new_tokens,
                               memory=memory)
    tok = jnp.argmax(logits[..., : cfg.vocab_size], -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    serve = jax.jit(make_serve_step(cfg))
    out = [tok]
    t1 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, lp, cache = serve(params, cache, tok)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t1
    print(f"arch={cfg.name} B={B} prompt={S} new={args.new_tokens}")
    print(f"prefill: {t_prefill:.2f}s  decode: "
          f"{dt / max(args.new_tokens - 1, 1) * 1000:.1f} ms/token")
    print("sample token ids:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
