"""Serving launcher: the multi-tenant rank-K decode server.

    PYTHONPATH=src python -m repro.launch.serve --jobs 12 --K 16 --L 64

The seed-era LM prefill + greedy-decode loop that used to live here is
retired — "serving" in this repo means decoding many concurrent
federated rounds, which is `repro.serve` (continuous-batching
DecoderBank, see docs/serving.md).  This module forwards to that CLI
so the launch entry point keeps working; the LM serve *step* itself
survives in `repro.launch.steps.make_serve_step` for the dry-run
pipeline.
"""
from __future__ import annotations

from repro.serve.cli import build_parser, main

__all__ = ["build_parser", "main"]

if __name__ == "__main__":
    main()
