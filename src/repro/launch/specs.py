"""Assigned input shapes and per-(arch, shape) ShapeDtypeStruct specs.

`input_specs(cfg, shape_name)` returns weak-type-correct stand-ins for
every model input — no device allocation; the dry-run lowers against
them (system-prompt pattern).

Decode shapes lower serve_step: ONE new token against a cache of
seq_len.  long_500k requires sub-quadratic attention: SSM/hybrid archs
run natively; full-attention archs run their sliding-window variant
(window = cfg.long_context_window; DESIGN.md §4), so all 10 archs
cover all 4 shapes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def needs_memory(cfg: ModelConfig) -> bool:
    return cfg.frontend is not None


def memory_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Frontend token count.  Vision: fixed patch budget.  Audio: the
    shape's sequence length IS the audio frame count (long-form audio
    is the seq axis for enc-dec)."""
    if cfg.frontend == "vision":
        return cfg.num_frontend_tokens
    if cfg.frontend == "audio":
        return shape.seq_len
    return 0


def decode_window(cfg: ModelConfig, shape: ShapeSpec) -> Optional[int]:
    """Window override for the attention caches of a decode shape.
    long_500k forces the sliding-window variant on full-attention
    archs; shapes <= 32k keep the arch's own window (full cache if
    the arch has none)."""
    if shape.name == "long_500k" and cfg.window is None:
        return cfg.long_context_window
    return cfg.window


def batch_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Inputs for train/prefill steps."""
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((B, S), jnp.int32),
    }
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    if needs_memory(cfg):
        M = memory_len(cfg, shape)
        batch["memory"] = sds((B, M, cfg.d_model), cfg.dtype)
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """(cache, token) stand-ins for serve_step."""
    from repro.models import transformer as tf
    B, S = shape.global_batch, shape.seq_len
    window = decode_window(cfg, shape)
    M = memory_len(cfg, shape) if needs_memory(cfg) else 0

    cache = jax.eval_shape(
        lambda: tf.make_decoder_cache(cfg, B, S, window, M))
    token = sds((B, 1), jnp.int32)
    return {"cache": cache, "token": token}
