"""Multi-pod dry-run: lower + compile every (architecture x input
shape) on the production mesh, record memory/cost/collective analysis.

The two os.environ lines below must stay the FIRST statements after
this docstring — before any other import, jax included: jax locks the
device count at first init, and ONLY the dry-run wants 512 placeholder
CPU devices (tests/benches see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--agg fednc_naive] \
        [--out EXPERIMENTS/dryrun.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCHITECTURES, get_config
from repro.launch import roofline as rl
from repro.launch import sharding as sh
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh, num_clients
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import transformer as tf
from repro.optim import adamw

DEFAULT_OUT = "EXPERIMENTS/dryrun_results.json"


def count_params(shapes_tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in
               jax.tree_util.tree_leaves(shapes_tree))


def count_active_params(shapes_tree: Any, cfg) -> int:
    """Active params per token: routed experts scaled by top_k/E."""
    flat = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
    total = 0.0
    for path, leaf in flat:
        name = "/".join(sh._key_str(k) for k in path)
        n = float(np.prod(leaf.shape))
        if cfg.moe is not None and "moe/w_" in name:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return int(total)


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    total = (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0))
    out["per_device_total_bytes"] = total
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in (ca or {}).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             agg_mode: str = "fednc_naive", keep_hlo: bool = False,
             moe_shard: str = "dmodel",
             mla_absorbed: bool = False,
             attn_bf16: bool = False,
             moe_act_shard: bool = False,
             grad_kshard: bool = False,
             agg_bf16: bool = False,
             q_chunk: int = 0,
             variant: str = "baseline") -> dict:
    """Lower + compile one (arch, shape, mesh) and extract analyses."""
    from repro.models import attention as attn_mod
    from repro.models import moe as moe_mod
    t0 = obs.clock()
    cfg = get_config(arch)
    sh.set_moe_inner_shard(moe_shard)
    attn_mod.set_attend_bf16(attn_bf16)
    if q_chunk:
        attn_mod.Q_CHUNK = q_chunk
    moe_mod.set_moe_act_spec(("model", "data", None)
                             if moe_act_shard else None)
    if mla_absorbed and cfg.mla is not None:
        from dataclasses import replace as _rp
        cfg = cfg.with_overrides(mla=_rp(cfg.mla, absorbed=True))
    shape = sp.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(np.prod(list(mesh.shape.values()))),
        "agg_mode": agg_mode if shape.kind == "train" else None,
        "variant": variant,
        "status": "started",
    }
    key = jax.random.PRNGKey(0)

    params_s = jax.eval_shape(lambda: tf.init_lm(key, cfg))
    n_params = count_params(params_s)
    n_active = count_active_params(params_s, cfg)
    rec["n_params"] = n_params
    rec["n_active_params"] = n_active
    param_sh = sh.param_shardings(params_s, mesh)

    with mesh:
        if shape.kind == "train":
            big = n_params > 3e10
            opt = adamw(1e-4, state_dtype=jnp.bfloat16 if big
                        else jnp.float32)
            opt_s = jax.eval_shape(opt.init, params_s)
            opt_sh = sh.opt_shardings(opt_s, mesh, params_s)
            batch = sp.batch_inputs(cfg, shape)
            batch_sh = sh.batch_shardings(batch, mesh)
            step = make_train_step(cfg, opt, num_clients=num_clients(mesh),
                                   agg_mode=agg_mode,
                                   kshard_grads=grad_kshard,
                                   agg_bf16=agg_bf16)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh, sh.replicated(mesh)),
                out_shardings=(param_sh, opt_sh, sh.replicated(mesh)),
            )
            key_s = jax.ShapeDtypeStruct(key.shape, key.dtype)
            lowered = jitted.lower(params_s, opt_s, batch, key_s)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            batch = sp.batch_inputs(cfg, shape)
            batch_sh = sh.batch_shardings(batch, mesh)
            window = sp.decode_window(cfg, shape)
            step = make_prefill_step(cfg, cache_len=shape.seq_len,
                                     window=window)
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_s, batch)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            d_in = sp.decode_inputs(cfg, shape)
            cache_sh = sh.cache_shardings(d_in["cache"], mesh)
            tok_sh = sh.batch_shardings({"t": d_in["token"]}, mesh)["t"]
            window = sp.decode_window(cfg, shape)
            step = make_serve_step(cfg, window=window)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, tok_sh),
                out_shardings=(tok_sh, tok_sh, cache_sh),
            )
            lowered = jitted.lower(params_s, d_in["cache"], d_in["token"])
            tokens = shape.global_batch
        t_lower = obs.clock()
        with obs.timed("launch.compile", cat="launch", arch=arch) as sw:
            compiled = lowered.compile()

    rec["lower_s"] = round(t_lower - t0, 2)
    rec["compile_s"] = round(sw.dur_s, 2)
    rec["memory_analysis"] = _memory_analysis_dict(compiled)
    rec["cost_analysis"] = _cost_analysis_dict(compiled)

    hlo = compiled.as_text()
    ana = rl.analyze_hlo(hlo)
    rec["hlo_analysis"] = {
        "flops_per_device": ana.flops,
        "memory_bytes_per_device": ana.memory_bytes,
        "collective_bytes_per_device": ana.collective_bytes,
        "collective_count": ana.collective_count,
        "collectives_by_type": ana.collectives_by_type,
        "n_while_loops": ana.n_while_loops,
    }
    if keep_hlo:
        rec["hlo_path"] = f"EXPERIMENTS/hlo/{arch}_{shape_name}_" \
            f"{rec['mesh']}_{agg_mode}_{variant}.txt"
        os.makedirs(os.path.dirname(rec["hlo_path"]), exist_ok=True)
        with open(rec["hlo_path"], "w") as f:
            f.write(hlo)

    rec["roofline"] = rl.roofline_terms(ana.flops, ana.memory_bytes,
                                        ana.collective_bytes)
    rec["tokens_per_step"] = tokens
    rec["model_flops"] = rl.model_flops(n_active, tokens,
                                        training=shape.kind == "train")
    chips = rec["chips"]
    if ana.flops > 0:
        rec["useful_flops_ratio"] = rec["model_flops"] / \
            (ana.flops * chips)
    rec["status"] = "ok"
    return rec


def append_result(rec: dict, out_path: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    # replace any previous record for the same key
    keyf = ("arch", "shape", "mesh", "agg_mode", "variant")
    results = [r for r in results
               if tuple(r.get(k) for k in keyf)
               != tuple(rec.get(k) for k in keyf)]
    results.append(rec)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(sp.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--agg", default="fednc_naive",
                    choices=["plain", "fednc_naive", "fednc_blocked"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on this mesh")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--moe-shard", default="dmodel",
                    choices=["dmodel", "dff"])
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--attn-bf16", action="store_true")
    ap.add_argument("--moe-act-shard", action="store_true")
    ap.add_argument("--grad-kshard", action="store_true")
    ap.add_argument("--agg-bf16", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--variant", default="baseline",
                    help="label for §Perf iteration records")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCHITECTURES:
            for s in sp.SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    n_ok = 0
    for arch, shape in pairs:
        label = f"{arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'})"
        try:
            rec = run_pair(arch, shape, multi_pod=args.multi_pod,
                           agg_mode=args.agg, keep_hlo=args.keep_hlo,
                           moe_shard=args.moe_shard,
                           mla_absorbed=args.mla_absorbed,
                           attn_bf16=args.attn_bf16,
                           moe_act_shard=args.moe_act_shard,
                           grad_kshard=args.grad_kshard,
                           agg_bf16=args.agg_bf16,
                           q_chunk=args.q_chunk,
                           variant=args.variant)
            n_ok += 1
            r = rec["roofline"]
            print(f"[OK] {label}: compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"bottleneck={r['bottleneck']} "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "agg_mode": args.agg, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"[FAIL] {label}: {type(e).__name__}: {e}", flush=True)
        append_result(rec, args.out)
    print(f"done: {n_ok}/{len(pairs)} ok", flush=True)


if __name__ == "__main__":
    main()
