"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e targets):

    compute    = dot_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = hbm_bytes_per_device / HBM_bw               (819e9)
    collective = collective_operand_bytes_per_device / ICI_bw (50e9)

Why not compiled.cost_analysis()?  XLA's HloCostAnalysis visits a
while-loop body ONCE — our models lax.scan the layer stack, so its
flops/bytes under-count by the trip count (verified experimentally).
Instead we parse the optimized per-device HLO ourselves and:

  * recover loop trip counts from each while-condition's comparison
    constant, propagating multipliers through nested loops/calls;
  * count dot FLOPs (2·|out|·K from lhs_contracting_dims) wherever the
    dot sits, times its computation's multiplier;
  * approximate HBM traffic as Σ (operand + result bytes) over
    kernel-level instructions (fusions, dots, copies, collectives) in
    non-fused computations — fusion boundaries are materialization
    points, fusion-internal temporaries stay in registers/VMEM;
  * sum collective operand bytes by op type (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), async pairs
    counted once.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")
_KERNEL_OPS = ("fusion", "dot", "convolution", "copy", "custom-call",
               "dynamic-update-slice", "dynamic-slice", "transpose",
               "reduce", "broadcast", "concatenate", "scatter", "gather",
               "sort", "iota", "reshape", "convert", "select", "compare",
               "add", "multiply", "subtract", "divide", "pad", "slice",
               "tuple", "get-tuple-element", "bitcast")
# ops whose bytes we count toward HBM traffic at computation scope.
# bitcast/tuple/get-tuple-element/reshape are free (aliasing).
_FREE_OPS = {"bitcast", "tuple", "get-tuple-element", "reshape",
             "parameter", "constant", "iota", "after-all"}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(?:\([^)]*\)|[a-z0-9\[\],{}<=\s]+?)\s*"
                    r"([a-z][a-z0-9\-]*)\(")
_COLL_RE = re.compile(
    r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(([^)]*)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WHILE_RE = re.compile(
    r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _dims(dims_str: str) -> tuple:
    return tuple(int(d) for d in dims_str.split(",")) if dims_str else ()


def _elems(dims: tuple) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _type_part(rhs: str) -> list[tuple[str, tuple]]:
    """Result type(s) at the start of an instruction RHS."""
    if rhs.startswith("("):
        head = rhs[: rhs.index(")") + 1]
    else:
        head = rhs.split("(")[0]
    return [(d, _dims(ds)) for d, ds in _SHAPE_RE.findall(head)]


def _shapes_bytes(shapes: list[tuple[str, tuple]]) -> int:
    return sum(_elems(dims) * _DTYPE_BYTES.get(d, 4) for d, dims in shapes)


@dataclass
class HloAnalysis:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_count: int = 0
    collectives_by_type: dict = field(default_factory=dict)
    n_while_loops: int = 0


def analyze_hlo(hlo_text: str) -> HloAnalysis:
    # ---------------- pass 1: index computations & instructions
    comps: dict[str, dict] = {}
    cur = "__toplevel__"

    def new_comp(name):
        comps.setdefault(name, {
            "colls": [], "whiles": [], "calls": [], "consts": [],
            "dots": [], "mem": 0.0, "fused": "fused" in name,
        })

    new_comp(cur)
    shapes: dict[str, list] = {}
    entry = None

    for line in hlo_text.splitlines():
        if (not line.startswith(" ") and "{" in line
                and "=" not in line.split("{")[0].split("(")[0]):
            head = line.split("(")[0]
            if "ENTRY" in head:
                head = head.replace("ENTRY", "")
            cur = head.strip().lstrip("%").strip()
            if line.startswith("ENTRY"):
                entry = cur
            new_comp(cur)
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        out_shapes = _type_part(rhs)
        if out_shapes:
            shapes[name] = out_shapes
        om = _OP_RE.match(rhs)
        op = om.group(1) if om else ""
        comp = comps[cur]

        if op == "while":
            wm = _WHILE_RE.search(rhs)
            if wm:
                comp["whiles"].append((wm.group(1), wm.group(2)))
        for callee in _CALL_RE.findall(rhs):
            comp["calls"].append(callee)
        bm = _BRANCH_RE.search(rhs)
        if bm:
            comp["calls"].extend(
                c.strip().lstrip("%") for c in bm.group(1).split(","))
        for cc in _CONST_RE.findall(rhs):
            v = int(cc)
            if 1 <= v <= 50_000_000:
                comp["consts"].append(v)

        # dot flops (count inside fused computations too)
        if op == "dot":
            operands = _OPERAND_RE.findall(rhs.split("(", 1)[1])
            lhs_name = operands[0] if operands else None
            lc = _LHS_CONTRACT_RE.search(rhs)
            if lhs_name in shapes and lc:
                lhs_dims = shapes[lhs_name][0][1]
                kdims = _dims(lc.group(1))
                K = 1
                for kd in kdims:
                    if kd < len(lhs_dims):
                        K *= lhs_dims[kd]
                out_elems = sum(_elems(d) for _, d in out_shapes)
                comp["dots"].append(2.0 * out_elems * K)

        # collectives
        cm = _COLL_RE.search(rhs)
        if cm and cm.group(2) != "-done":
            nb = 0
            for on in _OPERAND_RE.findall(cm.group(3)):
                if on in shapes and len(shapes[on]) == 1:
                    nb += _shapes_bytes(shapes[on])
            if nb == 0:
                nb = _shapes_bytes(out_shapes)
            comp["colls"].append((cm.group(1), nb))

        # HBM traffic at kernel granularity (non-fused computations).
        # Tuple-shaped operands (e.g. the whole while-carry tuple fed to
        # a fusion) are aliasing containers, not traffic: real reads go
        # through get-tuple-element names, which carry element shapes.
        if not comp["fused"] and op and op not in _FREE_OPS \
                and op != "while" and op != "conditional":
            nb = _shapes_bytes(out_shapes)
            arg_str = rhs.split("(", 1)[1] if "(" in rhs else ""
            arg_str = arg_str.split(")")[0]
            for on in _OPERAND_RE.findall(arg_str):
                if on in shapes and len(shapes[on]) == 1:
                    nb += _shapes_bytes(shapes[on])
            comp["mem"] += nb

    # ---------------- pass 2: multipliers via loop trip counts
    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if not cond or not cond["consts"]:
            return 1
        return max(cond["consts"])

    mult: dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0):
        if depth > 60 or name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for callee in comp["calls"]:
            visit(callee, m, depth + 1)
        for cond, body in comp["whiles"]:
            t = trip_count(cond)
            visit(cond, m * t, depth + 1)
            visit(body, m * t, depth + 1)

    if entry and entry in comps:
        visit(entry, 1.0)
    else:
        for name in comps:
            mult[name] = 1.0

    out = HloAnalysis()
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            m = 1.0 if name == entry else 0.0
        out.flops += m * sum(comp["dots"])
        out.memory_bytes += m * comp["mem"]
        out.n_while_loops += len(comp["whiles"])
        for op, nb in comp["colls"]:
            out.collective_bytes += m * nb
            out.collective_count += int(m)
            ent = out.collectives_by_type.setdefault(
                op, {"bytes": 0.0, "count": 0})
            ent["bytes"] += m * nb
            ent["count"] += int(m)
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes: float) -> dict:
    compute = flops_per_device / PEAK_FLOPS_BF16
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def model_flops(n_params_active: int, tokens: int, *,
                training: bool) -> float:
    """MODEL_FLOPS = 6·N·D train (fwd+bwd), 2·N·D inference."""
    mult = 6.0 if training else 2.0
    return mult * n_params_active * tokens
