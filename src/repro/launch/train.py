"""Production FL-LM training driver.

Runs federated training of any --arch config on the available device
mesh: the global batch splits into K client shards, each computes local
gradients, FedNC codes the updates across the client axis, the decoded
mean updates the global model.  On the CPU container use --reduced;
on a real TPU slice drop it and pass --mesh-data/--mesh-model.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --reduced --steps 50 --batch 8 --seq 128 --agg fednc_blocked
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import obs
from repro.configs import get_config, reduced_config
from repro.data.tokens import make_token_stream
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim import adamw, linear_warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--agg", default="fednc_blocked",
                    choices=["plain", "fednc_naive", "fednc_blocked"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="data axis size (0 = all devices)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    n_dev = len(jax.devices())
    dsize = args.mesh_data or max(n_dev // args.mesh_model, 1)
    mesh = Mesh(np.array(jax.devices()[: dsize * args.mesh_model])
                .reshape(dsize, args.mesh_model), ("data", "model"))
    print(f"arch={cfg.name} params mesh={dict(mesh.shape)} "
          f"agg={args.agg} clients={args.clients}")

    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg)
    n_params = sum(int(np.prod(l.shape)) for l in
                   jax.tree_util.tree_leaves(params))
    print(f"n_params={n_params / 1e6:.1f}M")

    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps))
    opt_state = opt.init(params)

    step_fn = make_train_step(cfg, opt, num_clients=args.clients,
                              agg_mode=args.agg)
    with mesh:
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        stream = make_token_stream(cfg.vocab_size, seed=0)
        losses = []
        t0 = obs.clock()
        for i in range(args.steps):
            b = stream.batch(args.batch, args.seq)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            if cfg.frontend:
                batch["memory"] = jnp.zeros(
                    (args.batch, cfg.num_frontend_tokens, cfg.d_model),
                    cfg.dtype)
            params, opt_state, loss = jstep(
                params, opt_state, batch, jax.random.fold_in(key, i))
            losses.append(float(loss))
            if (i + 1) % args.log_every == 0:
                dt = obs.clock() - t0
                print(f"step {i + 1:5d} loss={np.mean(losses[-args.log_every:]):.4f} "
                      f"({dt / (i + 1):.2f}s/step)", flush=True)
        print(f"final loss {np.mean(losses[-5:]):.4f} "
              f"(first {np.mean(losses[:5]):.4f})")

    if args.ckpt:
        from repro.checkpoint import save_pytree
        save_pytree(args.ckpt, params,
                    metadata={"arch": cfg.name, "steps": args.steps})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
