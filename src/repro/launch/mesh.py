"""Production mesh: TPU v5e, 256 chips/pod, (data, model) = (16, 16);
multi-pod adds a leading pod axis (2 pods = 512 chips).

A FUNCTION, not a module constant — importing this module must never
touch jax device state (tests run with 1 CPU device; only dryrun.py
forces 512 host devices)."""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline tables.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_clients(mesh) -> int:
    """FedNC 'clients' = data-parallel groups (DESIGN.md §3b)."""
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
