"""Step builders: train_step (with FedNC gradient aggregation across
the client/data axis), prefill_step, serve_step (single-token decode).

FedNC-on-mesh (DESIGN.md §3b): the global batch is split into K client
shards (K = data-parallel groups).  Per-client gradients come from one
vmap'd backward pass; aggregation then runs one of:

  plain         — mean over clients (the reliable-fabric reference)
  fednc_naive   — paper-literal: encode ALL clients' full gradients
                  (C = A·G), decode by solve, average.  The encode
                  einsum forces the full gradient stack onto each
                  data shard — K× collective bytes, the faithful
                  baseline.
  fednc_blocked — NC-aware blocked codec: gradients split into K
                  blocks, coded block-wise (all-to-all shaped), ≈
                  all-reduce wire cost.  The §Perf optimized variant.

Everything is pure pjit — XLA SPMD materializes the collectives, which
launch/roofline.py then reads back out of the compiled HLO.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates


# ---------------------------------------------------------------------------
# FedNC gradient aggregation (float field, pjit formulation)
# ---------------------------------------------------------------------------

def _mix_matrix(key, K: int) -> jnp.ndarray:
    return jax.random.normal(key, (K, K), jnp.float32)


def float_inv(A: jnp.ndarray) -> jnp.ndarray:
    """Gauss-Jordan inverse of a small KxK matrix, unrolled.

    Pure einsum/where ops — deliberately NOT jnp.linalg.inv, whose
    LU custom-call cannot be SPMD-partitioned (it would force XLA to
    gather/replicate whatever touches it).  A is tiny and replicated;
    everything downstream stays a partitionable matmul."""
    K = A.shape[0]
    M = jnp.concatenate([A.astype(jnp.float32), jnp.eye(K)], axis=1)
    for col in range(K):
        # partial pivot: pick the largest |entry| at/below the diagonal
        colvals = jnp.abs(M[:, col])
        rows = jnp.arange(K)
        cand = jnp.where(rows >= col, colvals, -jnp.inf)
        piv = jnp.argmax(cand)
        row_c, row_p = M[col], M[piv]
        M = M.at[col].set(row_p).at[piv].set(row_c)
        M = M.at[col].set(M[col] / M[col, col])
        factors = M[:, col].at[col].set(0.0)
        M = M - factors[:, None] * M[col][None, :]
    return M[:, K:]


def aggregate_gradients(grads: Any, key, K: int, mode: str, *,
                        code_in_bf16: bool = False) -> Any:
    """grads: tree of (K, ...) per-client grads -> tree of (...) means.

    code_in_bf16 (§Perf): keep the coded packet stream in the gradient
    dtype (bf16) with f32 accumulation instead of materializing an f32
    copy of the full K× gradient stack — halves the coded wire bytes.
    The protocol-level GF path (core.rlnc) is unaffected (bit-exact on
    raw bytes); this is the float-field mesh variant only."""
    if mode == "plain":
        return jax.tree_util.tree_map(lambda g: jnp.mean(g, 0), grads)

    A = _mix_matrix(key, K)
    A_inv = float_inv(A)

    def _cast(g):
        return g if code_in_bf16 else g.astype(jnp.float32)

    def _mm(M, x):
        return jnp.einsum("ik,k...->i...", M.astype(x.dtype), x,
                          preferred_element_type=jnp.float32) \
            .astype(x.dtype)

    if mode == "fednc_naive":
        def enc_dec(g):
            gf = _cast(g).reshape(K, -1)
            C = _mm(A, gf)                          # encode (eq. 4)
            X = _mm(A_inv, C)                       # GE decode
            return jnp.mean(X.astype(jnp.float32), 0) \
                .reshape(g.shape[1:]).astype(g.dtype)
        return jax.tree_util.tree_map(enc_dec, grads)

    if mode == "fednc_blocked":
        def enc_dec(g):
            gf = _cast(g).reshape(K, -1)
            L = gf.shape[1]
            pad = (-L) % K
            gf = jnp.pad(gf, ((0, 0), (0, pad)))
            m = gf.shape[1] // K
            gb = gf.reshape(K, K, m)                # (client, block, m)
            C = jnp.einsum("ik,kjm->ijm", A.astype(gb.dtype), gb,
                           preferred_element_type=jnp.float32) \
                .astype(gb.dtype)                   # encode per block
            X = jnp.einsum("ki,ijm->kjm", A_inv.astype(C.dtype), C,
                           preferred_element_type=jnp.float32)
            mean = jnp.mean(X, 0).reshape(-1)[:L]   # (block, m) -> flat
            return mean.reshape(g.shape[1:]).astype(g.dtype)
        return jax.tree_util.tree_map(enc_dec, grads)

    raise ValueError(f"unknown aggregation mode {mode!r}")


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    num_clients: int, agg_mode: str = "fednc_naive",
                    window: Optional[int] = None,
                    kshard_grads: bool = False,
                    agg_bf16: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch, key).

    kshard_grads (§Perf): pin the per-client gradient stack to the FL-
    natural layout — client axis on `data`, trailing dim on `model` —
    instead of letting SPMD guess.  Without it, SPMD's layout choice
    for the (K, ...) grad tree swings the whole backward pass (measured:
    the 'plain' mode compiles 3x the FLOPs of 'fednc_naive' purely from
    propagation differences)."""
    K = num_clients

    def loss_fn(params, batch):
        loss, _ = tf.lm_loss(params, batch, cfg, window=window, remat=True)
        return loss

    def _kshard(g):
        from jax.sharding import PartitionSpec as P
        if g.ndim < 2:
            spec = P("data")
        else:
            last = "model" if g.shape[-1] % 16 == 0 else None
            spec = P("data", *([None] * (g.ndim - 2)), last)
        try:
            return jax.lax.with_sharding_constraint(g, spec)
        except Exception:
            return g

    def train_step(params, opt_state, batch, key):
        # split global batch into K client shards (client dim leading,
        # aligned with the data mesh axis)
        def split(x):
            return x.reshape((K, x.shape[0] // K) + x.shape[1:])
        cb = jax.tree_util.tree_map(split, batch)

        losses, grads = jax.vmap(
            lambda b: jax.value_and_grad(loss_fn)(params, b))(cb)
        if kshard_grads:
            grads = jax.tree_util.tree_map(_kshard, grads)

        gmean = aggregate_gradients(grads, key, K, agg_mode,
                                    code_in_bf16=agg_bf16)
        updates, opt_state = optimizer.update(gmean, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, jnp.mean(losses)

    return train_step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int,
                      window: Optional[int] = None) -> Callable:
    def prefill_step(params, batch):
        return tf.prefill(params, batch["tokens"], cfg,
                          cache_len=cache_len, window=window,
                          memory=batch.get("memory"))
    return prefill_step


def make_serve_step(cfg: ModelConfig, *,
                    window: Optional[int] = None) -> Callable:
    """Single-token greedy decode step: (params, cache, token) ->
    (next_token, logprob, cache)."""
    def serve_step(params, cache, token):
        logits, cache = tf.decode_step(params, token, cache, cfg,
                                       window=window)
        logits = logits.astype(jnp.float32)
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vmask[None, None], logits, -1e30)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]
        return nxt, lp, cache
    return serve_step
