"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Right-aligned template rules keyed on tree-path substrings: a template
like (DATA, MODEL) applies to the trailing dims of the leaf, leading
dims (e.g. the lax.scan group dim) replicate.  Dims that do not divide
the mesh axis fall back to replication (logged) — this is how e.g.
arctic's 56 heads or kv_heads < 16 degrade gracefully (DESIGN.md §6).
"""
from __future__ import annotations

import logging
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import batch_axes

log = logging.getLogger("repro.sharding")

DATA, MODEL = "data", "model"


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def _fits(dim: int, mesh: Mesh, axis: Optional[str]) -> Optional[str]:
    if axis is None:
        return None
    size = mesh.shape[axis]
    if dim % size == 0:
        return axis
    log.debug("dim %d not divisible by %s=%d -> replicated",
              dim, axis, size)
    return None


def _apply_template(shape: tuple, template: tuple, mesh: Mesh,
                    align: str = "right") -> P:
    """Template entries map to trailing (right) or leading (left) dims."""
    spec: list = [None] * len(shape)
    t = list(template)
    if align == "right":
        for i, ax in enumerate(reversed(t)):
            d = len(shape) - 1 - i
            if d >= 0:
                spec[d] = _fits(shape[d], mesh, ax)
    else:
        for d, ax in enumerate(t):
            if d < len(shape):
                spec[d] = _fits(shape[d], mesh, ax)
    return P(*spec)


# MoE expert-weight inner sharding:
#   'dmodel' (baseline/ZeRO): w_gate/w_up (E, d@data, ff) — the d_model
#       contraction dim is sharded, so SPMD must all-gather expert
#       weights before every routed matmul (per token-group scan step!)
#   'dff' (§Perf variant): (E, d, ff@data) — contraction dim whole, the
#       sharded dim flows through the expert hidden; no weight gather.
MOE_INNER = "dmodel"


def set_moe_inner_shard(mode: str) -> None:
    global MOE_INNER
    assert mode in ("dmodel", "dff")
    globals()["MOE_INNER"] = mode


def _param_rules():
    up_tmpl = ((MODEL, DATA, None) if MOE_INNER == "dmodel"
               else (MODEL, None, DATA))
    return [
        ("moe/w_gate", up_tmpl, "left_skip_scan"),
        ("moe/w_up", up_tmpl, "left_skip_scan"),
        ("moe/w_down", (MODEL, DATA, None), "left_skip_scan"),
        ("moe/router", (DATA, None), "right"),
        ("embed/table", (MODEL, DATA), "right"),
        ("lm_head", (DATA, MODEL), "right"),
        ("conv_w", (None, MODEL), "right"),
        ("lam", (MODEL,), "right"),
    ]


def param_spec_for(path: str, shape: tuple, mesh: Mesh) -> P:
    if len(shape) == 0:
        return P()
    for sub, template, align in _param_rules():
        if sub in path:
            if align == "left_skip_scan":
                # expert weights: (E, din, dout) or (G, E, din, dout)
                offset = len(shape) - 3
                spec = [None] * len(shape)
                for j, ax in enumerate(template):
                    d = offset + j
                    spec[d] = _fits(shape[d], mesh, ax)
                return P(*spec)
            return _apply_template(shape, template, mesh, align)
    if len(shape) == 1:
        return P(None)
    # generic matrix: in-dim -> data (ZeRO), out-dim -> model
    return _apply_template(shape, (DATA, MODEL), mesh)


def param_shardings(param_shapes: Any, mesh: Mesh) -> Any:
    """ShapeDtypeStruct tree -> NamedSharding tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        spec = param_spec_for(_path_str(path), tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batches & caches
# ---------------------------------------------------------------------------

def batch_spec(shape: tuple, mesh: Mesh) -> P:
    """Leading dim = global batch -> (pod,)data when divisible."""
    axes = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if len(shape) == 0:
        return P()
    if shape[0] % total == 0 and shape[0] > 0:
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch_shapes: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_spec(tuple(l.shape), mesh)),
        batch_shapes)


_CACHE_RULES = [
    # (leaf name, template) right-aligned
    ("k", (None, MODEL, None, None)),      # (B, slots, KV, hd)
    ("v", (None, MODEL, None, None)),
    ("ckv", (None, MODEL, None)),          # (B, slots, r)
    ("krope", (None, MODEL, None)),
    ("conv", (None, None, MODEL)),         # (B, cw-1, w)
    ("h", (None, MODEL)),                  # (B, w)
    ("C", (None, None, None, None)),       # mlstm matrix memory
    ("n", (None, None, None)),
    ("m", (None, None)),
    ("c", (None, MODEL)),                  # slstm
    ("pos", ()),
]


def cache_spec_for(path: str, shape: tuple, mesh: Mesh) -> P:
    name = path.rsplit("/", 1)[-1]
    for leaf_name, template in _CACHE_RULES:
        if name == leaf_name:
            spec = list(_apply_template(shape, template, mesh))
            # batch dim: right-aligned template leaves leading dims None;
            # shard the batch dim (first of the template window) on data
            boff = len(shape) - len(template)
            if len(template) and boff >= 0:
                axes = batch_axes(mesh)
                total = int(np.prod([mesh.shape[a] for a in axes]))
                if shape[boff] % max(total, 1) == 0:
                    spec[boff] = axes if len(axes) > 1 else axes[0]
            return P(*spec)
    return P(*([None] * len(shape)))


def cache_shardings(cache_shapes: Any, mesh: Mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        spec = cache_spec_for(_path_str(path), tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# coded packets (repro.engine lane parallelism)
# ---------------------------------------------------------------------------

def replicated_spec(ndim: int) -> P:
    """All-dims-replicated PartitionSpec (coding matrices: tiny, everywhere)."""
    return P(*([None] * ndim))


def coded_spec(ndim: int, mesh: Mesh, axis: str = "data") -> P:
    """Spec for coded symbol matrices (..., L): lanes shard on `axis`.

    RLNC mixes clients (rows); every lane (column) is independent, so
    the engine's shard_map splits L across the mesh with zero
    communication.  Falls back to full replication when the axis is
    absent (e.g. the single-device test mesh).
    """
    if ndim == 0 or axis not in mesh.axis_names:
        return replicated_spec(ndim)
    return P(*([None] * (ndim - 1) + [axis]))


def opt_shardings(opt_shapes: Any, mesh: Mesh, params_template: Any
                  ) -> Any:
    """Optimizer slots mirror the parameter tree's specs; step scalar
    replicates.  Works because slots are tree_map images of params."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
    out = []
    for path, leaf in flat:
        p = _path_str(path)
        if leaf.ndim == 0:
            out.append(replicated(mesh))
        else:
            out.append(NamedSharding(
                mesh, param_spec_for(p, tuple(leaf.shape), mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)
