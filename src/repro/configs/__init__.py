"""Architecture registry: one module per assigned architecture.

Each module exports get_config() (the full assigned spec, citation in
its docstring) and reduced_config() (the CPU smoke-test variant:
<=2-ish layers, d_model<=512, <=4 experts)."""
from __future__ import annotations

import importlib

ARCHITECTURES = (
    "starcoder2_15b",
    "recurrentgemma_9b",
    "llama3_2_vision_90b",
    "xlstm_125m",
    "seamless_m4t_medium",
    "qwen3_4b",
    "arctic_480b",
    "deepseek_v2_236b",
    "qwen2_72b",
    "qwen3_8b",
)

# CLI ids (dashes) -> module names
_ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}
_ALIASES.update({
    "starcoder2-15b": "starcoder2_15b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen3-4b": "qwen3_4b",
    "arctic-480b": "arctic_480b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-8b": "qwen3_8b",
})


def _module(name: str):
    key = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).get_config()


def reduced_config(name: str):
    return _module(name).reduced_config()


def list_architectures() -> tuple:
    return tuple(sorted(set(_ALIASES) - set(ARCHITECTURES)))
