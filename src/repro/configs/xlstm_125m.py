"""xLSTM-125M [arXiv:2405.04517]: alternating mLSTM (matrix memory,
parallel-trainable) and sLSTM (scalar memory, sequential) blocks,
5:1 ratio; d_ff=0 — projections live inside the blocks.  Attention-
free -> long_500k native."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        arch_type="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        scan_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm",
                      "slstm"),
        act="gelu",
        norm="layernorm",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        arch_type="ssm",
        num_layers=2,
        d_model=128,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        scan_pattern=("mlstm", "slstm"),
        act="gelu",
        norm="layernorm",
        vocab_pad_multiple=16,
    )
