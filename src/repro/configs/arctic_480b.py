"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base]:
dense-MoE hybrid — every layer routes 128 experts top-2 (d_ff 4864)
with a parallel dense residual MLP.  56 heads do not divide the
16-way model axis: attention is head-replicated, MoE expert-parallel
(DESIGN.md §6 — attention is <2% of step FLOPs here)."""
from repro.models.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        arch_type="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        scan_pattern=("moe_residual",),
        act="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            d_ff_expert=4864,
            dense_residual=True,
            d_ff_residual=4864,
        ),
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        scan_pattern=("moe_residual",),
        act="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=4,
            top_k=2,
            d_ff_expert=256,
            dense_residual=True,
            d_ff_residual=256,
        ),
        vocab_pad_multiple=16,
    )
