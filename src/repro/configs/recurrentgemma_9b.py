"""RecurrentGemma-9B [arXiv:2402.19427] (Griffin): RG-LRU recurrent
blocks + local sliding-window attention in a 2:1 pattern
(rglru, rglru, local-attn); 38 layers = 12 scanned groups + 2 trailing
recurrent blocks.  Natively sub-quadratic -> long_500k runs as-is."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        scan_pattern=("rglru", "rglru", "local"),
        act="geglu",
        norm="rmsnorm",
        window=2048,
        lru_width=4096,
        conv_width=4,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        arch_type="hybrid",
        num_layers=5,          # one scanned group + (rglru, rglru) tail
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        d_ff=512,
        vocab_size=512,
        scan_pattern=("rglru", "rglru", "local"),
        act="geglu",
        norm="rmsnorm",
        window=32,
        lru_width=256,
        conv_width=4,
        vocab_pad_multiple=16,
    )
