"""StarCoder2-15B [arXiv:2402.19173]: dense GQA decoder, GeLU MLP,
QKV bias, LayerNorm, sliding-window 4096 (the release trains with SWA
— so the long_500k variant is *faithful* here)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        arch_type="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        scan_pattern=("dense",),
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
        window=4096,
        rope_theta=1e5,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        scan_pattern=("dense",),
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
        window=64,
        vocab_pad_multiple=16,
    )
