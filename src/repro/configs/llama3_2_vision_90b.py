"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled
per assignment]: 100 decoder layers, every 5th a gated cross-attention
layer over vision-tower patch embeddings.  The ViT tower + projector
is a STUB (assignment carve-out): input_specs provides projected patch
embeddings (B, num_frontend_tokens, d_model)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        arch_type="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        scan_pattern=("dense", "dense", "dense", "dense", "xattn"),
        act="swiglu",
        norm="rmsnorm",
        rope_theta=5e5,
        frontend="vision",
        num_frontend_tokens=4096,    # 4 tiles x ~1024 projected patches
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke",
        arch_type="vlm",
        num_layers=5,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        scan_pattern=("dense", "dense", "dense", "dense", "xattn"),
        act="swiglu",
        norm="rmsnorm",
        frontend="vision",
        num_frontend_tokens=16,
        vocab_pad_multiple=16,
    )
