"""Qwen3-4B [hf:Qwen/Qwen3-8B family]: dense GQA with per-head
QK-RMSNorm, head_dim 128 (> d_model/num_heads), SwiGLU, RMSNorm."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        arch_type="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        scan_pattern=("dense",),
        qk_norm=True,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        scan_pattern=("dense",),
        qk_norm=True,
        act="swiglu",
        norm="rmsnorm",
        vocab_pad_multiple=16,
    )
