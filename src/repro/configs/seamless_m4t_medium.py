"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder; the speech
frontend (mel + conformer conv) is a STUB (assignment carve-out) —
input_specs provides frame embeddings (B, frames, d_model); the
12-layer bidirectional encoder and the 12-layer decoder (self + cross
+ MLP) are real.  Vocab 256206 pads to 256256 (multiple of 256) for
clean sharding (DESIGN.md §6)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        arch_type="audio",
        num_layers=12,           # decoder layers
        encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        scan_pattern=("dec",),
        act="gelu",
        norm="layernorm",
        frontend="audio",
        num_frontend_tokens=1024,   # default frames; shapes may override
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke",
        arch_type="audio",
        num_layers=2,
        encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=500,
        scan_pattern=("dec",),
        act="gelu",
        norm="layernorm",
        frontend="audio",
        num_frontend_tokens=16,
        vocab_pad_multiple=16,
    )
