"""Qwen2-72B [arXiv:2407.10671]: dense GQA with QKV bias, SwiGLU."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        arch_type="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        scan_pattern=("dense",),
        qkv_bias=True,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        scan_pattern=("dense",),
        qkv_bias=True,
        act="swiglu",
        norm="rmsnorm",
        vocab_pad_multiple=16,
    )
