"""DeepSeek-V2-236B [arXiv:2405.04434]: MLA attention (kv_lora 512,
q_lora 1536, decoupled RoPE head 64) + MoE with 2 shared and 160
routed experts, top-6 (d_ff_expert 1536); layer 0 is dense
(d_ff 12288) — modeled as the unrolled prefix."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=12288,                 # dense prefix layer MLP
        vocab_size=102400,
        prefix_kinds=("dense",),
        scan_pattern=("moe",),
        act="swiglu",
        norm="rmsnorm",
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            nope_head_dim=128,
            rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            d_ff_expert=1536,
            num_shared_experts=2,
            d_ff_residual=1536,
        ),
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        arch_type="moe",
        num_layers=3,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        prefix_kinds=("dense",),
        scan_pattern=("moe",),
        act="swiglu",
        norm="rmsnorm",
        mla=MLAConfig(
            kv_lora_rank=64,
            q_lora_rank=96,
            nope_head_dim=32,
            rope_head_dim=16,
            v_head_dim=32,
        ),
        moe=MoEConfig(
            num_experts=4,
            top_k=2,
            d_ff_expert=128,
            num_shared_experts=1,
            d_ff_residual=128,
        ),
        vocab_pad_multiple=16,
    )
