"""DecodeServer: many federated rounds decoded by one program.

Each *job* (one federated round) owns a slot in a
`repro.engine.DecoderBank` — its private reduced-basis [B | Y] state —
while a `FifoScheduler` coalesces whatever packets arrived since the
last tick, across ALL jobs, into one padded block per tick.  The
server's whole inner loop is therefore: drain queues -> one
`ingest` dispatch -> scan the rank trajectories for jobs that just hit
rank K -> emit a :class:`JobCompletion`, free the slot, admit the next
waiting job.  Seeded and materialized wire formats coexist per packet
(`use_seed` in the tick block), and packets for already-complete jobs
are counted and dropped.

:func:`serve_trace` is the offline driver: replay a recorded
`ServeTrace` as fast as the server can take it and report throughput
(packets/s) and per-job completion latency percentiles — the numbers
BENCH_serve.json publishes.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro import obs
from repro.core.seeds import expand_rows_jit
from repro.engine import DecoderBank

from .scheduler import FifoScheduler
from .trace import ServeTrace


def payload_digest(arr) -> str:
    """Stable 16-hex digest of a decoded payload (fixture pinning)."""
    a = np.ascontiguousarray(np.asarray(arr, np.uint8))
    return hashlib.sha1(a.tobytes()).hexdigest()[:16]


@dataclass(frozen=True)
class JobCompletion:
    """Emitted the tick a job's basis reaches rank K."""

    job: int
    k: int
    l: int
    arrivals: int        # packets ingested when rank K was reached
    latency_s: float     # wall time from submit to completion tick
    payload_sha: str     # payload_digest of the decoded (k, l) matrix


@dataclass
class ServeReport:
    """What one served trace looked like from the server's side."""

    jobs: int
    completed: int
    packets_offered: int
    packets_ingested: int
    late_dropped: int
    ticks: int
    dispatches: int
    wall_s: float
    max_concurrent: int
    completions: list[JobCompletion] = field(default_factory=list)
    metrics: Optional[dict] = None   # fednc-metrics-v1 snapshot

    @property
    def packets_per_s(self) -> float:
        return self.packets_ingested / max(self.wall_s, 1e-12)

    def latency_percentiles(self) -> tuple[float, float]:
        """(p50, p99) job completion latency in seconds."""
        if not self.completions:
            return (float("nan"), float("nan"))
        lat = np.array([c.latency_s for c in self.completions])
        return (float(np.percentile(lat, 50)),
                float(np.percentile(lat, 99)))


@dataclass
class _JobState:
    k: int
    l: int
    slot: Optional[int] = None
    arrivals: int = 0          # valid packets ingested so far
    offered: int = 0
    t_submit: float = 0.0
    backlog: list = field(default_factory=list)   # offers while waiting
    done: Optional[JobCompletion] = None
    payload: Optional[np.ndarray] = None


class DecodeServer:
    """Continuous-batching multi-tenant rank-K decode server."""

    def __init__(self, slots: int, K: int, L: int, s: int = 8,
                 g_tick: int = 8, batched: bool = True):
        self.bank = DecoderBank(slots, K, L, s)
        self.sched = FifoScheduler(slots, K, L, g_tick)
        self.batched = bool(batched)
        self._slot_job = np.full((slots,), -1, np.int64)
        self._jobs: dict[int, _JobState] = {}
        self._waiting: deque[int] = deque()
        m = self.metrics = obs.MetricsRegistry()
        self._m_ticks = m.counter("serve.ticks")
        self._m_ingested = m.counter("serve.packets_ingested")
        self._m_late = m.counter("serve.late_dropped")
        self._m_depth = m.gauge("serve.queue_depth")
        self._m_busy = m.gauge("serve.slots_busy")
        # batch-size buckets in packets (powers of two up to a full
        # slots x g_tick block); latency buckets log-spaced 10us..100s
        self._m_batch = m.histogram(
            "serve.ingest_batch",
            bounds=[2 ** i for i in range(11)])
        self._m_latency = m.histogram("serve.job_latency_s")

    # legacy attribute names (pre-obs) kept as counter-backed views
    @property
    def ticks(self) -> int:
        return self._m_ticks.value

    @property
    def late_dropped(self) -> int:
        return self._m_late.value

    @property
    def packets_ingested(self) -> int:
        return self._m_ingested.value

    @property
    def max_concurrent(self) -> int:
        return int(self._m_busy.max or 0)

    # -- job lifecycle ----------------------------------------------------

    def submit(self, job: int, k: int, l: Optional[int] = None) -> None:
        """Admit a round: slot it if one is free, else queue it."""
        job = int(job)
        if job in self._jobs:
            raise ValueError(f"job {job} already submitted")
        st = _JobState(k=int(k), l=self.bank.L if l is None else int(l),
                       t_submit=obs.clock())
        self._jobs[job] = st
        free = np.nonzero(self._slot_job < 0)[0]
        if free.size:
            self._place(job, int(free[0]))
        else:
            self._waiting.append(job)

    def _place(self, job: int, slot: int) -> None:
        st = self._jobs[job]
        self.bank.open(slot, st.k, st.l)
        self._slot_job[slot] = job
        st.slot = slot
        self._m_busy.set(int(np.sum(self._slot_job >= 0)))
        for seed, row, payload in st.backlog:
            self.sched.enqueue(slot, seed=seed, payload=payload, row=row)
        st.backlog.clear()

    def offer(self, job: int, payload, *, seed: int = 0,
              row=None) -> bool:
        """Hand the server one coded tuple for `job`.

        `row=None` means the seeded wire format (expand `seed`
        in-dispatch); a materialized (k,) `row` means the classic
        format.  Returns False if the job already completed (the
        packet is dropped and counted in ``late_dropped``)."""
        st = self._jobs[int(job)]
        if st.done is not None:
            self._m_late.inc()
            return False
        st.offered += 1
        if st.slot is None:
            st.backlog.append((int(seed), row, payload))
        else:
            self.sched.enqueue(st.slot, seed=seed, payload=payload,
                               row=row)
        return True

    def result(self, job: int) -> np.ndarray:
        """Decoded (k, l) payload matrix of a completed job."""
        st = self._jobs[int(job)]
        if st.payload is None:
            raise ValueError(f"job {job} has not completed")
        return st.payload

    def completion(self, job: int) -> Optional[JobCompletion]:
        return self._jobs[int(job)].done

    @property
    def completions(self) -> list[JobCompletion]:
        return sorted((st.done for st in self._jobs.values()
                       if st.done is not None),
                      key=lambda c: c.job)

    # -- the serving loop -------------------------------------------------

    def tick(self) -> bool:
        """One scheduler tick: drain queues, one ingest dispatch,
        emit completions, admit waiting jobs.  False if idle."""
        tr = obs.get_tracer()
        depth = self.sched.pending
        if depth == 0:
            return False
        self._m_depth.set(depth)
        self._m_busy.set(int(np.sum(self._slot_job >= 0)))
        if tr.enabled:
            tr.counter("serve.queue_depth", depth)
            tr.counter("serve.slots_busy",
                       int(np.sum(self._slot_job >= 0)))
        block = self.sched.next_block()
        if block is None:                      # pragma: no cover
            return False
        rows, seeds, use, valid, C = block
        batch = int(valid.sum())
        with tr.span("serve.ingest", cat="serve", packets=batch) as sp:
            ranks = sp.fence(self.bank.ingest(
                rows=rows, seeds=seeds, use_seed=use, valid=valid, C=C,
                batched=self.batched))
        self._m_ticks.inc()
        self._m_ingested.inc(batch)
        self._m_batch.observe(batch)
        freed = []
        for slot in np.nonzero(valid.any(axis=1))[0]:
            job = int(self._slot_job[slot])
            st = self._jobs[job]
            if st.done is None and (ranks[slot] >= st.k).any():
                p0 = int(np.argmax(ranks[slot] >= st.k))
                arrivals = st.arrivals + int(valid[slot, : p0 + 1].sum())
                st.payload = np.asarray(self.bank.payload(slot))
                latency = obs.clock() - st.t_submit
                st.done = JobCompletion(
                    job=job, k=st.k, l=st.l, arrivals=arrivals,
                    latency_s=latency,
                    payload_sha=payload_digest(st.payload))
                self._m_latency.observe(latency)
                tr.instant("serve.complete", cat="serve", job=job,
                           arrivals=arrivals)
                self._m_late.inc(self.sched.clear(slot))
                self.bank.close(slot)
                self._slot_job[slot] = -1
                freed.append(slot)
            st.arrivals += int(valid[slot].sum())
        for slot in freed:
            if self._waiting:
                self._place(self._waiting.popleft(), int(slot))
        return True

    def drain(self, max_ticks: int = 1_000_000) -> int:
        """Tick until every queue is empty; returns ticks run."""
        n = 0
        while n < max_ticks and self.tick():
            n += 1
        return n


def serve_trace(trace: ServeTrace, *, slots: int = 8,
                g_tick: int = 8, batched: bool = True) -> ServeReport:
    """Replay a recorded trace through a DecodeServer at full speed.

    Jobs are submitted when their first packet arrives; a tick fires
    whenever some slot's queue reaches `g_tick` (and at end-of-trace,
    `drain`).  Given the same trace, the per-job decoded payloads and
    completion arrival counts are independent of `g_tick`, `slots`,
    and `batched` — only the wall-clock numbers change.
    """
    srv = DecodeServer(slots, trace.max_k, trace.max_l, s=trace.s,
                       g_tick=g_tick, batched=batched)
    rows_at: dict[int, np.ndarray] = {}
    for job in trace.jobs:
        if not job.seeded:
            idx = trace.packet_indices(job.job)
            A = np.asarray(expand_rows_jit(trace.row_seeds[idx], job.K,
                                           trace.s))
            for p, i in enumerate(idx):
                rows_at[int(i)] = A[p]
    offered = 0
    with obs.timed("serve.trace", cat="serve",  # fednc: ignore[FNC002] every tick() reads ranks/payloads to host, so the region is fenced by construction
                   jobs=trace.n_jobs) as sw:
        for i in range(trace.n_packets):
            j = int(trace.job_of[i])
            meta = trace.jobs[j]
            if j not in srv._jobs:
                srv.submit(j, meta.K, meta.L)
            srv.offer(j, trace.payloads[i, : meta.L],
                      seed=int(trace.row_seeds[i]), row=rows_at.get(i))
            offered += 1
            while srv.sched.max_depth >= g_tick:
                srv.tick()
        srv.drain()
    comps = srv.completions
    return ServeReport(
        jobs=trace.n_jobs, completed=len(comps),
        packets_offered=offered,
        packets_ingested=srv.packets_ingested,
        late_dropped=srv.late_dropped,
        ticks=srv.ticks, dispatches=srv.bank.dispatches,
        wall_s=sw.dur_s, max_concurrent=srv.max_concurrent,
        completions=comps, metrics=srv.metrics.snapshot())
