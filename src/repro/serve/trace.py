"""Multi-tenant arrival traces: the decode server's input format.

A *trace* is the interleaved packet stream a decode server hears when
many federated rounds (jobs) are in flight at once: for every packet,
an arrival time, the job it belongs to, its coding metadata, and its
coded payload.  Coding metadata is always recorded as the 4-byte uint32
row seed that generated the coefficients (`repro.core.seeds`); whether
a packet *ships* that seed (the seeded wire format) or the materialized
K-symbol row it expands to is a per-job property (``ServeJob.seeded``),
so one trace exercises both wire formats side by side.

:func:`poisson_multitenant_trace` builds the benchmark/test workload:
job round-starts form a Poisson process (exponential inter-arrival
gaps), and each job's packets arrive with gaps drawn from a
`repro.sim` straggler distribution — the same generating model the
network simulator uses, merged across tenants into one global
time-ordered stream.

Traces serialize to JSON (:meth:`ServeTrace.save` / ``load``) so a
recorded trace can be committed as a regression fixture
(tests/data/) and replayed bit-identically.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.gf import get_field
from repro.core.seeds import expand_rows_jit
from repro.sim import STRAGGLER_PROFILES, DistSpec


@dataclass(frozen=True)
class ServeJob:
    """One tenant round: generation size K, payload width L, wire format."""

    job: int
    K: int
    L: int
    seeded: bool          # ships 4-byte seeds (True) or K-symbol rows
    t_start: float        # round start on the trace clock


@dataclass
class ServeTrace:
    """A recorded multi-tenant packet stream, in arrival order."""

    s: int
    jobs: list[ServeJob]
    times: np.ndarray        # (G,) nondecreasing trace clock
    job_of: np.ndarray       # (G,) job id per packet
    row_seeds: np.ndarray    # (G,) uint32 coefficient seed per packet
    payloads: np.ndarray     # (G, max_l) uint8, zero-padded per packet
    extra: dict = field(default_factory=dict)   # fixture expectations etc.

    @property
    def n_packets(self) -> int:
        return int(self.times.shape[0])

    @property
    def n_jobs(self) -> int:
        return len(self.jobs)

    @property
    def max_k(self) -> int:
        return max(j.K for j in self.jobs)

    @property
    def max_l(self) -> int:
        return max(j.L for j in self.jobs)

    def packet_indices(self, job: int) -> np.ndarray:
        """Trace positions of one job's packets, in arrival order."""
        return np.nonzero(self.job_of == job)[0]

    def wire_bytes(self) -> int:
        """Total bytes this trace occupies on the wire (header+payload,
        per each job's format — the number BENCH_serve divides by)."""
        from repro.core.packets import packet_wire_bytes
        total = 0
        for j in self.jobs:
            n = int(self.packet_indices(j.job).shape[0])
            total += n * packet_wire_bytes(j.K, j.L, self.s,
                                           seeded=j.seeded)
        return total

    # -- JSON round trip (regression fixtures) ----------------------------

    def to_json(self) -> str:
        doc = {
            "schema": "fednc-serve-trace-v1",
            "s": self.s,
            "jobs": [{"job": j.job, "K": j.K, "L": j.L,
                      "seeded": j.seeded, "t_start": j.t_start}
                     for j in self.jobs],
            "times": [float(t) for t in self.times],
            "job_of": [int(j) for j in self.job_of],
            "row_seeds": [int(x) for x in self.row_seeds],
            "payloads": [[int(b) for b in row] for row in self.payloads],
            "extra": self.extra,
        }
        return json.dumps(doc, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "ServeTrace":
        doc = json.loads(text)
        if doc.get("schema") != "fednc-serve-trace-v1":
            raise ValueError(f"not a serve trace: {doc.get('schema')!r}")
        jobs = [ServeJob(job=j["job"], K=j["K"], L=j["L"],
                         seeded=j["seeded"], t_start=j["t_start"])
                for j in doc["jobs"]]
        return cls(
            s=doc["s"], jobs=jobs,
            times=np.asarray(doc["times"], np.float64),
            job_of=np.asarray(doc["job_of"], np.int64),
            row_seeds=np.asarray(doc["row_seeds"], np.uint32),
            payloads=np.asarray(doc["payloads"], np.uint8).reshape(
                len(doc["times"]), -1),
            extra=doc.get("extra", {}),
        )

    def save(self, path) -> None:
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "ServeTrace":
        return cls.from_json(pathlib.Path(path).read_text())


def _per_job(value, n_jobs: int, name: str) -> list:
    if isinstance(value, (int, np.integer, bool, np.bool_)):
        return [value] * n_jobs
    out = list(value)
    if len(out) != n_jobs:
        raise ValueError(f"{name} must be scalar or length {n_jobs}")
    return out


def poisson_multitenant_trace(
        n_jobs: int, K, L, s: int = 8, *,
        rate: float = 4.0, gap: str | DistSpec = "exponential",
        extra_packets: int = 6, seeded="mixed",
        duplicate_rate: float = 0.0, seed: int = 0) -> ServeTrace:
    """The benchmark workload: Poisson round starts, straggler gaps.

    `n_jobs` tenant rounds start at exponential(1/`rate`) spacing; job
    j uploads ``K_j + extra_packets`` coded tuples whose inter-arrival
    gaps are drawn from the `gap` straggler distribution
    (`repro.sim.STRAGGLER_PROFILES` name or a DistSpec).  `K`/`L` may
    be scalars or per-job sequences; ``seeded="mixed"`` alternates the
    wire format per job (or pass a bool / per-job sequence).

    ``duplicate_rate`` re-sends the previous packet (same seed, same
    payload) with that probability — the redundant-arrival case every
    decoder must treat as a no-op.  Everything flows from one
    ``np.random.Generator(seed)`` plus per-job jax payload keys, so
    equal arguments give bit-identical traces.
    """
    rng = np.random.default_rng(seed)
    Ks = _per_job(K, n_jobs, "K")
    Ls = _per_job(L, n_jobs, "L")
    if seeded == "mixed":
        seeds_flag = [j % 2 == 0 for j in range(n_jobs)]
    else:
        seeds_flag = [bool(x) for x in _per_job(seeded, n_jobs,
                                                "seeded")]
    gap_spec = (STRAGGLER_PROFILES[gap] if isinstance(gap, str)
                else gap)
    field_ = get_field(s)
    starts = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), n_jobs))

    jobs: list[ServeJob] = []
    times, job_of, row_seeds, payloads = [], [], [], []
    max_l = max(Ls)
    pkey = jax.random.PRNGKey(np.uint32(seed))
    for j in range(n_jobs):
        k, l = int(Ks[j]), int(Ls[j])
        n = k + int(extra_packets)
        jobs.append(ServeJob(job=j, K=k, L=l, seeded=seeds_flag[j],
                             t_start=float(starts[j])))
        seeds_j = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
        if duplicate_rate > 0:
            dup = rng.random(n) < duplicate_rate
            dup[0] = False
            idx = np.arange(n)
            idx[dup] = idx[dup] - 1
            seeds_j = seeds_j[idx]
        P = field_.random_elements(jax.random.fold_in(pkey, j), (k, l))
        A = expand_rows_jit(seeds_j, k, s)
        C = np.asarray(field_.matmul(A, P))
        t = starts[j] + np.cumsum(gap_spec.sample(rng, n))
        pad = np.zeros((n, max_l), np.uint8)
        pad[:, :l] = C
        times.append(t)
        job_of.append(np.full(n, j, np.int64))
        row_seeds.append(seeds_j)
        payloads.append(pad)

    times = np.concatenate(times)
    order = np.argsort(times, kind="stable")
    return ServeTrace(
        s=s, jobs=jobs,
        times=times[order],
        job_of=np.concatenate(job_of)[order],
        row_seeds=np.concatenate(row_seeds)[order],
        payloads=np.concatenate(payloads)[order],
    )
