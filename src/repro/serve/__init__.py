"""repro.serve — the multi-tenant decode server.

trace.py     — ServeJob/ServeTrace arrival streams (JSON round-trip)
               and the Poisson multi-tenant workload generator built
               on repro.sim's straggler distributions.
scheduler.py — FifoScheduler: per-slot FIFO queues drained into
               fixed-shape padded tick blocks (continuous batching).
server.py    — DecodeServer over engine.DecoderBank: one ingest
               dispatch per tick across every in-flight round, rank-K
               completion events, waiting-job admission; serve_trace
               offline replay driver -> ServeReport.
cli.py       — `python -m repro.serve`: build/load a trace, serve it,
               print and optionally dump the report.

See docs/serving.md for the architecture guide.
"""
from .scheduler import FifoScheduler
from .server import (DecodeServer, JobCompletion, ServeReport,
                     payload_digest, serve_trace)
from .trace import ServeJob, ServeTrace, poisson_multitenant_trace

__all__ = [
    "DecodeServer", "FifoScheduler", "JobCompletion", "ServeJob",
    "ServeReport", "ServeTrace", "payload_digest",
    "poisson_multitenant_trace", "serve_trace",
]
