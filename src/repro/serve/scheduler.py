"""Tick scheduler: per-slot FIFO queues -> fixed-shape padded blocks.

The continuous-batching trick (sglang-style chunked prefill, applied
to decoders): arrivals for any mix of jobs are queued per slot, and
every scheduler *tick* drains up to ``g_tick`` tuples from EVERY
slot's queue into one fixed ``(slots, g_tick)`` padded block.  Because
the block shape never changes, the whole run is served by a single
compiled program (one `DecoderBank.ingest` dispatch per tick), no
matter how lopsided the per-job traffic is.

Queues are strictly FIFO and blocks are front-packed (valid tuples at
positions ``0..n-1``, zero padding behind them), which is what makes
per-job completion *arrival counts* invariant to the tick size — the
determinism property tests/test_serve.py pins down.
"""
from __future__ import annotations

from collections import deque

import numpy as np


class FifoScheduler:
    """Per-slot FIFO arrival queues coalesced into padded tick blocks."""

    def __init__(self, slots: int, K: int, L: int, g_tick: int = 8):
        if g_tick < 1:
            raise ValueError("g_tick must be >= 1")
        self.slots, self.K, self.L = int(slots), int(K), int(L)
        self.g_tick = int(g_tick)
        self._q: list[deque] = [deque() for _ in range(self.slots)]

    def enqueue(self, slot: int, *, seed: int, payload,
                row=None) -> None:
        """Queue one coded tuple for `slot`.

        `row` is the materialized (k,) coding row for the materialized
        wire format, or None for the seeded format (the 4-byte `seed`
        is expanded in-dispatch).  `payload` is the (l,) coded symbols;
        both are zero-padded here to the bank-wide (K,)/(L,) shapes.
        """
        use = row is None
        r = np.zeros((self.K,), np.uint8)
        if row is not None:
            row = np.asarray(row, np.uint8)
            r[: row.shape[0]] = row
        c = np.zeros((self.L,), np.uint8)
        payload = np.asarray(payload, np.uint8)
        c[: payload.shape[0]] = payload
        self._q[int(slot)].append((r, np.uint32(seed), use, c))

    def queue_depth(self, slot: int) -> int:
        return len(self._q[int(slot)])

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._q)

    @property
    def max_depth(self) -> int:
        return max((len(q) for q in self._q), default=0)

    def clear(self, slot: int) -> int:
        """Drop a slot's queued tuples (job completed); returns count."""
        n = len(self._q[int(slot)])
        self._q[int(slot)].clear()
        return n

    def next_block(self):
        """Drain <= g_tick tuples per slot into one padded tick block.

        Returns ``(rows, seeds, use_seed, valid, C)`` with shapes
        ``(slots, g_tick, K) / (slots, g_tick) x3 / (slots, g_tick, L)``
        ready for `DecoderBank.ingest`, or None if every queue is empty.
        """
        if self.pending == 0:
            return None
        J, g = self.slots, self.g_tick
        rows = np.zeros((J, g, self.K), np.uint8)
        seeds = np.zeros((J, g), np.uint32)
        use = np.zeros((J, g), bool)
        valid = np.zeros((J, g), bool)
        C = np.zeros((J, g, self.L), np.uint8)
        for j in range(J):
            q = self._q[j]
            for p in range(min(g, len(q))):
                r, sd, u, c = q.popleft()
                rows[j, p] = r
                seeds[j, p] = sd
                use[j, p] = u
                valid[j, p] = True
                C[j, p] = c
        return rows, seeds, use, valid, C
