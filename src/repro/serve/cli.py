"""Decode-server CLI.

    PYTHONPATH=src python -m repro.serve --jobs 12 --K 16 --L 64

Builds (or loads, ``--trace``) a multi-tenant arrival trace, replays
it through the continuous-batching DecodeServer, and prints the
throughput / latency report.  ``--sequential`` switches the bank to
the one-dispatch-per-job baseline; ``--json`` dumps the report.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .server import serve_trace
from .trace import ServeTrace, poisson_multitenant_trace


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Multi-tenant continuous-batching decode server")
    ap.add_argument("--jobs", type=int, default=12,
                    help="tenant rounds in the generated trace")
    ap.add_argument("--K", type=int, default=16,
                    help="generation size per job")
    ap.add_argument("--L", type=int, default=64,
                    help="payload symbols per packet")
    ap.add_argument("--extra", type=int, default=6,
                    help="redundant packets per job beyond K")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson round-start rate")
    ap.add_argument("--gap", default="exponential",
                    help="straggler profile for packet gaps")
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent jobs held in the decoder bank")
    ap.add_argument("--g-tick", type=int, default=8,
                    help="max packets per job per scheduler tick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sequential", action="store_true",
                    help="per-job dispatch baseline (no batching)")
    ap.add_argument("--trace", default=None,
                    help="serve a recorded trace JSON instead")
    ap.add_argument("--save-trace", default=None,
                    help="record the generated trace to this path")
    ap.add_argument("--json", default=None,
                    help="write the report JSON here")
    return ap


def main(argv=None) -> dict:
    args = build_parser().parse_args(argv)
    if args.trace:
        trace = ServeTrace.load(args.trace)
    else:
        trace = poisson_multitenant_trace(
            args.jobs, args.K, args.L, rate=args.rate, gap=args.gap,
            extra_packets=args.extra, seeded="mixed", seed=args.seed)
    if args.save_trace:
        trace.save(args.save_trace)
    rep = serve_trace(trace, slots=args.slots, g_tick=args.g_tick,
                      batched=not args.sequential)
    p50, p99 = rep.latency_percentiles()
    doc = {
        "mode": "sequential" if args.sequential else "batched",
        "jobs": rep.jobs, "completed": rep.completed,
        "packets": rep.packets_ingested,
        "late_dropped": rep.late_dropped,
        "ticks": rep.ticks, "dispatches": rep.dispatches,
        "max_concurrent": rep.max_concurrent,
        "wall_s": rep.wall_s,
        "packets_per_s": rep.packets_per_s,
        "p50_latency_s": p50, "p99_latency_s": p99,
        "completions": [{"job": c.job, "k": c.k,
                         "arrivals": c.arrivals,
                         "payload_sha": c.payload_sha}
                        for c in rep.completions],
    }
    print(f"served {rep.jobs} jobs ({rep.completed} complete) "
          f"mode={doc['mode']} slots={args.slots} g_tick={args.g_tick}")
    print(f"packets={rep.packets_ingested} ticks={rep.ticks} "
          f"dispatches={rep.dispatches} "
          f"max_concurrent={rep.max_concurrent}")
    print(f"{rep.packets_per_s:,.0f} packets/s  "
          f"p50={p50 * 1e3:.1f} ms  p99={p99 * 1e3:.1f} ms")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(doc, indent=2))
        print(f"wrote {args.json}")
    return doc


if __name__ == "__main__":
    main()
