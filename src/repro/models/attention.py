"""Attention: GQA (RoPE, qk-norm, bias, sliding window), MLA
(DeepSeek-V2 latent attention), and cross-attention — with prefill /
decode KV-cache paths.

Long sequences use a q-chunked formulation (lax.scan over query blocks)
so scores never materialize at (S, S): this is the flash-attention
memory pattern expressed in pure JAX (the Pallas kernel variant is an
optional perf path; XLA fuses this one well on TPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLAConfig, ModelConfig
from .layers import apply_rope, dense_apply, dense_init, norm_apply, norm_init

CHUNK_THRESHOLD = 8192   # direct attention below, q-chunked above
Q_CHUNK = 512

# §Perf knob: keep attention operands in bf16 (accumulate in f32 via
# preferred_element_type) instead of materializing f32 copies of Q/K/V
# and the probability matrix — halves attention HBM traffic.
ATTEND_BF16 = False


def set_attend_bf16(flag: bool) -> None:
    globals()["ATTEND_BF16"] = flag


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_self_attention(key, cfg: ModelConfig) -> dict:
    if cfg.mla is not None:
        return _init_mla(key, cfg)
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * hd, bias=cfg.qkv_bias,
                         dtype=cfg.dtype),
        "wk": dense_init(ks[1], d, KV * hd, bias=cfg.qkv_bias,
                         dtype=cfg.dtype),
        "wv": dense_init(ks[2], d, KV * hd, bias=cfg.qkv_bias,
                         dtype=cfg.dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = norm_init(hd, "rmsnorm", cfg.dtype)
        p["knorm"] = norm_init(hd, "rmsnorm", cfg.dtype)
    return p


def _init_mla(key, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype=cfg.dtype),
        "q_norm": norm_init(m.q_lora_rank, "rmsnorm", cfg.dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, H * qd, dtype=cfg.dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype=cfg.dtype),
        "kv_norm": norm_init(m.kv_lora_rank, "rmsnorm", cfg.dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, H * m.nope_head_dim,
                           dtype=cfg.dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim,
                           dtype=cfg.dtype),
        "w_kr": dense_init(ks[5], d, m.rope_head_dim, dtype=cfg.dtype),
        "wo": dense_init(ks[6], H * m.v_head_dim, d, dtype=cfg.dtype),
    }


def init_cross_attention(key, cfg: ModelConfig) -> dict:
    """KV from frontend/encoder memory; same head layout as self-attn."""
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * hd, dtype=cfg.dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype=cfg.dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype=cfg.dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype=cfg.dtype),
    }


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, T, KV, hd) -> (B, T, KV*groups, hd) by repetition (GQA)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _attend(q, k, v, *, causal: bool, window: Optional[int],
            q_offset, kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,T,H,hd).  Masked softmax attention.

    q_offset: absolute position of q[0] minus position of k[0] (so
    query i attends keys j with j <= i + q_offset, and, with a window,
    j > i + q_offset - window).
    kv_len: optional valid length of k/v (ring-buffer decode).
    """
    B, Sq, H, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    if ATTEND_BF16:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
    else:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    qi = jnp.arange(Sq)[:, None] + q_offset          # absolute q index
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((Sq, T), bool)
    if causal:
        mask &= kj <= qi
        if window is not None:
            mask &= kj > qi - window
    if kv_len is not None:
        mask &= kj < kv_len
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if ATTEND_BF16:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _attend_chunked(q, k, v, *, causal: bool, window: Optional[int],
                    chunk: int = 0) -> jnp.ndarray:
    """Same as _attend (q_offset=0) but scanned over query chunks so the
    (S, S) score matrix never materializes."""
    chunk = chunk or Q_CHUNK      # module global: §Perf --q-chunk knob
    B, S, H, hd = q.shape
    pad = (-S) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = qp.shape[1] // chunk
    qs = qp.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qc = args
        out = _attend(qc, k, v, causal=causal, window=window,
                      q_offset=i * chunk)
        return None, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs))
    vd = outs.shape[-1]          # value head dim (MLA: != q head dim)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, -1, H, vd)
    return out[:, :S]


# ---------------------------------------------------------------------------
# self-attention: train / prefill / decode
# ---------------------------------------------------------------------------

def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int]) -> dict:
    """Allocate an empty cache.  Windowed caches are ring buffers of
    `window` slots; full caches hold max_len slots."""
    if cfg.mla is not None:
        m = cfg.mla
        slots = min(window, max_len) if window else max_len
        return {
            "ckv": jnp.zeros((batch, slots, m.kv_lora_rank), cfg.dtype),
            "krope": jnp.zeros((batch, slots, m.rope_head_dim), cfg.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    slots = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, slots, KV, hd), cfg.dtype),
        "v": jnp.zeros((batch, slots, KV, hd), cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def apply_self_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                         window: Optional[int],
                         cache: Optional[dict] = None,
                         positions: Optional[jnp.ndarray] = None):
    """Returns (y, new_cache).  cache=None -> train (no cache out).
    x: (B, S, d).  S>1 with cache -> prefill (fills cache);
    S==1 with cache -> single-token decode."""
    if cfg.mla is not None:
        return _apply_mla(p, x, cfg, window=window, cache=cache,
                          positions=positions)
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = H // KV
    if positions is None:
        base = cache["pos"] if cache is not None else 0
        positions = base + jnp.arange(S)[None, :]

    q = dense_apply(p["wq"], x).reshape(B, S, H, hd)
    k = dense_apply(p["wk"], x).reshape(B, S, KV, hd)
    v = dense_apply(p["wv"], x).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = norm_apply(p["qnorm"], q)
        k = norm_apply(p["knorm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None or S > 1:
        kf = _expand_kv(k, groups)
        vf = _expand_kv(v, groups)
        if S > CHUNK_THRESHOLD:
            out = _attend_chunked(q, kf, vf, causal=True, window=window)
        else:
            out = _attend(q, kf, vf, causal=True, window=window, q_offset=0)
        new_cache = None
        if cache is not None:       # prefill: persist the (ring) tail
            new_cache = _fill_cache(cache, k, v, S)
    else:
        new_cache = _append_cache(cache, k, v)
        kv_len = jnp.minimum(new_cache["pos"], new_cache["k"].shape[1])
        kf = _expand_kv(new_cache["k"], groups)
        vf = _expand_kv(new_cache["v"], groups)
        # ring buffer: score with true positions unnecessary — softmax is
        # permutation-invariant given the validity mask; window recency
        # is enforced by buffer size.
        out = _attend(q, kf, vf, causal=False, window=None,
                      q_offset=0, kv_len=kv_len)
    y = dense_apply(p["wo"], out.reshape(B, S, H * hd))
    return y, new_cache


def _fill_cache(cache: dict, k, v, S: int) -> dict:
    """Prefill: write the last `slots` keys/values into the ring buffer,
    aligned so absolute position p occupies slot p % slots (decode then
    continues the ring seamlessly).  pos records the absolute count."""
    slots = cache["k"].shape[1]
    take = min(S, slots)
    kt = k[:, S - take:]
    vt = v[:, S - take:]
    if take == slots and S % slots:
        kt = jnp.roll(kt, S % slots, axis=1)
        vt = jnp.roll(vt, S % slots, axis=1)
    newk = jax.lax.dynamic_update_slice(
        cache["k"], kt.astype(cache["k"].dtype), (0, 0, 0, 0))
    newv = jax.lax.dynamic_update_slice(
        cache["v"], vt.astype(cache["v"].dtype), (0, 0, 0, 0))
    return {"k": newk, "v": newv, "pos": jnp.asarray(S, jnp.int32)}


def _append_cache(cache: dict, k, v) -> dict:
    """Decode: write one token at pos % slots (ring)."""
    slots = cache["k"].shape[1]
    idx = cache["pos"] % slots
    newk = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
    newv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
    return {"k": newk, "v": newv, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _latent_attend(q_lat, q_rope, ckv, krope, *, scale: float,
                   causal: bool, window: Optional[int], q_offset,
                   kv_len: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Absorbed-MLA attention: scores in the latent space, K/V never
    expanded per head.  q_lat: (B,Sq,H,r), q_rope: (B,Sq,H,rd),
    ckv: (B,T,r), krope: (B,T,rd).  Returns out_lat (B,Sq,H,r)."""
    B, Sq, H, r = q_lat.shape
    T = ckv.shape[1]
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32))) * scale
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((Sq, T), bool)
    if causal:
        mask &= kj <= qi
        if window is not None:
            mask &= kj > qi - window
    if kv_len is not None:
        mask &= kj < kv_len
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkr->bqhr", probs, ckv.astype(jnp.float32))
    return out.astype(q_lat.dtype)


def _latent_attend_chunked(q_lat, q_rope, ckv, krope, *, scale, causal,
                           window, chunk: int = 0) -> jnp.ndarray:
    chunk = chunk or Q_CHUNK      # module global: §Perf --q-chunk knob
    B, S, H, r = q_lat.shape
    pad = (-S) % chunk
    zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ql, qr = zp(q_lat), zp(q_rope)
    n = ql.shape[1] // chunk
    qls = ql.reshape(B, n, chunk, H, r).transpose(1, 0, 2, 3, 4)
    qrs = qr.reshape(B, n, chunk, H, -1).transpose(1, 0, 2, 3, 4)

    def body(_, xs):
        i, qc, qrc = xs
        return None, _latent_attend(qc, qrc, ckv, krope, scale=scale,
                                    causal=causal, window=window,
                                    q_offset=i * chunk, kv_len=None)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qls, qrs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, -1, H, r)
    return out[:, :S]

def _apply_mla(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
               window: Optional[int], cache: Optional[dict],
               positions: Optional[jnp.ndarray]):
    m: MLAConfig = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    nd, rd, vd = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    if positions is None:
        base = cache["pos"] if cache is not None else 0
        positions = base + jnp.arange(S)[None, :]

    # queries: low-rank then up
    cq = norm_apply(p["q_norm"], dense_apply(p["w_dq"], x))
    q = dense_apply(p["w_uq"], cq).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # latent kv + shared rope key
    ckv = norm_apply(p["kv_norm"], dense_apply(p["w_dkv"], x))  # (B,S,r)
    krope = apply_rope(
        dense_apply(p["w_kr"], x).reshape(B, S, 1, rd),
        positions, cfg.rope_theta,
    )[:, :, 0]                                                   # (B,S,rd)

    kv_len = None
    if cache is not None and S == 1:
        slots = cache["ckv"].shape[1]
        idx = cache["pos"] % slots
        cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0)),
            "krope": jax.lax.dynamic_update_slice(
                cache["krope"], krope.astype(cache["krope"].dtype),
                (0, idx, 0)),
            "pos": cache["pos"] + 1,
        }
        ckv_all, krope_all = cache["ckv"], cache["krope"]
        kv_len = jnp.minimum(cache["pos"], slots)
        causal = False
    else:
        ckv_all, krope_all = ckv, krope
        causal = True

    T = ckv_all.shape[1]
    scale = 1.0 / np.sqrt(nd + rd)
    if m.absorbed:
        # score & combine in latent space: K/V never expand to
        # (B, T, H, nd) — trades latent-rank score FLOPs for H× less
        # HBM traffic (the memory-bound §Perf variant).
        r = m.kv_lora_rank
        w_uk = p["w_uk"]["w"].reshape(r, H, nd)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        if causal and S > CHUNK_THRESHOLD:
            out_lat = _latent_attend_chunked(
                q_lat, q_rope, ckv_all, krope_all, scale=scale,
                causal=True, window=window)
        else:
            out_lat = _latent_attend(
                q_lat, q_rope, ckv_all, krope_all, scale=scale,
                causal=causal, window=window if causal else None,
                q_offset=0, kv_len=kv_len)
        w_uv = p["w_uv"]["w"].reshape(r, H, vd)
        out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv)
    else:
        k_nope = dense_apply(p["w_uk"], ckv_all).reshape(B, T, H, nd)
        vv = dense_apply(p["w_uv"], ckv_all).reshape(B, T, H, vd)
        k_rope_b = jnp.broadcast_to(krope_all[:, :, None, :],
                                    (B, T, H, rd))
        k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

        if causal and S > CHUNK_THRESHOLD:
            out = _attend_chunked(q_full, k_full, vv, causal=True,
                                  window=window)
        else:
            out = _attend(q_full, k_full, vv, causal=causal,
                          window=window if causal else None,
                          q_offset=0, kv_len=kv_len)
    y = dense_apply(p["wo"], out.reshape(B, S, H * vd))

    new_cache = cache
    if cache is not None and S > 1:   # prefill fill (ring-aligned)
        slots = cache["ckv"].shape[1]
        take = min(S, slots)
        ct = ckv[:, S - take:]
        rt = krope[:, S - take:]
        if take == slots and S % slots:
            ct = jnp.roll(ct, S % slots, axis=1)
            rt = jnp.roll(rt, S % slots, axis=1)
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ct.astype(cache["ckv"].dtype), (0, 0, 0)),
            "krope": jax.lax.dynamic_update_slice(
                cache["krope"], rt.astype(cache["krope"].dtype), (0, 0, 0)),
            "pos": jnp.asarray(S, jnp.int32),
        }
    return y, new_cache


# ---------------------------------------------------------------------------
# cross-attention (VLM image layers, enc-dec decoder)
# ---------------------------------------------------------------------------

def precompute_cross_kv(p: dict, memory: jnp.ndarray, cfg: ModelConfig
                        ) -> dict:
    """Project encoder/frontend memory to K/V once (reused every step)."""
    B, M, _ = memory.shape
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": dense_apply(p["wk"], memory).reshape(B, M, KV, hd),
        "v": dense_apply(p["wv"], memory).reshape(B, M, KV, hd),
    }


def apply_cross_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                          memory: Optional[jnp.ndarray] = None,
                          mem_kv: Optional[dict] = None) -> jnp.ndarray:
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = H // KV
    if mem_kv is None:
        mem_kv = precompute_cross_kv(p, memory, cfg)
    q = dense_apply(p["wq"], x).reshape(B, S, H, hd)
    kf = _expand_kv(mem_kv["k"], groups)
    vf = _expand_kv(mem_kv["v"], groups)
    out = _attend(q, kf, vf, causal=False, window=None, q_offset=0)
    return dense_apply(p["wo"], out.reshape(B, S, H * hd))
