"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM
(xLSTM) — each with a parallel train/prefill path and an O(1)-per-token
decode path carrying explicit recurrent state.

TPU notes: the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t is
lowered with `jax.lax.associative_scan` (log-depth, mapped onto the
VPU); mLSTM's train path uses its quadratic parallel form (attention-
like, MXU-friendly) with log-space gate stabilization; sLSTM is
inherently sequential (its normalizer/max-state is non-associative) and
uses `lax.scan` — that cost is intrinsic to the architecture, not an
implementation artifact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_apply, dense_init, norm_apply, norm_init


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a = sigmoid(Λ)^(8r) spreads over (0.9, 0.999)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 3.0, 8.0)
    return {
        "w_in": dense_init(ks[1], d, w, dtype=cfg.dtype),
        "w_gate": dense_init(ks[2], d, w, dtype=cfg.dtype),   # GeGLU branch
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w),
                                     jnp.float32) * 0.1).astype(cfg.dtype),
        "lam": lam,
        "w_a": dense_init(ks[4], w, w, dtype=cfg.dtype),      # recurrence gate
        "w_x": dense_init(ks[5], w, w, dtype=cfg.dtype),      # input gate
        "w_out": dense_init(jax.random.fold_in(key, 9), w, d,
                            dtype=cfg.dtype),
    }


def make_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


_C_RGLRU = 8.0


def _rglru_gates(p: dict, u: jnp.ndarray):
    """u: (..., w) post-conv branch input -> (a, bx) gate terms."""
    r = jax.nn.sigmoid(dense_apply(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_x"], u).astype(jnp.float32))
    log_a = -_C_RGLRU * r * jax.nn.softplus(p["lam"])     # log a_t < 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = mult * i * u.astype(jnp.float32)
    return a, bx


def apply_rglru(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[dict] = None):
    """x: (B, S, d) -> (y, new_state).  state!=None & S==1: decode."""
    B, S, d = x.shape
    u = dense_apply(p["w_in"], x)                     # (B, S, w)
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x))   # GeGLU output gate

    cw = cfg.conv_width
    if state is None or S > 1:
        # causal depthwise conv over time
        upad = jnp.pad(u.astype(jnp.float32), ((0, 0), (cw - 1, 0), (0, 0)))
        conv = sum(upad[:, i: i + S] * p["conv_w"][i].astype(jnp.float32)
                   for i in range(cw))
        a, bx = _rglru_gates(p, conv.astype(x.dtype))
        # h_t = a_t h_{t-1} + b_t via associative scan over time
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        aT = jnp.swapaxes(a, 0, 1)                    # (S, B, w)
        bT = jnp.swapaxes(bx, 0, 1)
        _, hT = jax.lax.associative_scan(combine, (aT, bT), axis=0)
        h = jnp.swapaxes(hT, 0, 1)                    # (B, S, w)
        new_state = None
        if state is not None:                          # prefill
            new_state = {
                "h": h[:, -1],
                "conv": upad[:, S: S + cw - 1]
                if S >= cw - 1 else jnp.zeros_like(state["conv"]),
            }
    else:
        # decode: one step
        hist = jnp.concatenate(
            [state["conv"], u.astype(jnp.float32)], axis=1)  # (B, cw, w)
        conv = sum(hist[:, i] * p["conv_w"][i].astype(jnp.float32)
                   for i in range(cw))[:, None]              # (B, 1, w)
        a, bx = _rglru_gates(p, conv.astype(x.dtype))
        h = a * state["h"][:, None] + bx                     # (B, 1, w)
        new_state = {"h": h[:, 0], "conv": hist[:, 1:]}

    y = dense_apply(p["w_out"], (h.astype(x.dtype) * gate))
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory, exponential gating
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, proj_factor: float = 2.0) -> dict:
    d = cfg.d_model
    di = int(d * proj_factor)
    H = cfg.num_heads
    assert di % H == 0
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], d, 2 * di, dtype=cfg.dtype),
        "wq": dense_init(ks[1], di, di, dtype=cfg.dtype),
        "wk": dense_init(ks[2], di, di, dtype=cfg.dtype),
        "wv": dense_init(ks[3], di, di, dtype=cfg.dtype),
        "w_i": dense_init(ks[4], di, H, dtype=cfg.dtype),
        "w_f": dense_init(ks[5], di, H, dtype=cfg.dtype),
        "norm": norm_init(di, "rmsnorm", cfg.dtype),
        "w_down": dense_init(ks[6], di, d, dtype=cfg.dtype),
    }


def make_mlstm_state(cfg: ModelConfig, batch: int,
                     proj_factor: float = 2.0) -> dict:
    di = int(cfg.d_model * proj_factor)
    H = cfg.num_heads
    dh = di // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def apply_mlstm(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[dict] = None,
                proj_factor: float = 2.0):
    B, S, d = x.shape
    H = cfg.num_heads
    up = dense_apply(p["w_up"], x)
    a, g = jnp.split(up, 2, axis=-1)                 # (B,S,di) each
    di = a.shape[-1]
    dh = di // H

    q = dense_apply(p["wq"], a).reshape(B, S, H, dh)
    k = dense_apply(p["wk"], a).reshape(B, S, H, dh) / np.sqrt(dh)
    v = dense_apply(p["wv"], a).reshape(B, S, H, dh)
    log_i = (dense_apply(p["w_i"], a).astype(jnp.float32)
             .transpose(0, 2, 1))                    # (B,H,S) input gate
    log_f = jax.nn.log_sigmoid(
        dense_apply(p["w_f"], a).astype(jnp.float32)).transpose(0, 2, 1)

    if state is None or S > 1:
        st0 = state or make_mlstm_state_from(B, H, dh)
        h, end_state = _mlstm_chunkwise(q, k, v, log_i, log_f, st0)
        new_state = end_state if state is not None else None
    else:
        # recurrent decode step
        C, n, m_prev = state["C"], state["n"], state["m"]
        li = log_i[:, :, 0]
        lf = log_f[:, :, 0]
        m_new = jnp.maximum(lf + m_prev, li)         # (B,H)
        fprime = jnp.exp(lf + m_prev - m_new)
        iprime = jnp.exp(li - m_new)
        kh = k[:, 0].astype(jnp.float32)             # (B,H,dh)
        vh = v[:, 0].astype(jnp.float32)
        qh = q[:, 0].astype(jnp.float32)
        C = fprime[..., None, None] * C + \
            iprime[..., None, None] * jnp.einsum("bhd,bhe->bhde", kh, vh)
        n = fprime[..., None] * n + iprime[..., None] * kh
        num = jnp.einsum("bhde,bhd->bhe", C, qh)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qh)),
                          jnp.exp(-m_new)) + 1e-6
        h = (num / den[..., None])[:, None]          # (B,1,H,dh)
        new_state = {"C": C, "n": n, "m": m_new}

    hflat = h.reshape(B, S, di).astype(x.dtype)
    out = norm_apply(p["norm"], hflat) * jax.nn.silu(g)
    return dense_apply(p["w_down"], out), new_state


def make_mlstm_state_from(B: int, H: int, dh: int) -> dict:
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }


MLSTM_CHUNK = 256


def _mlstm_chunkwise(q, k, v, log_i, log_f, state: dict,
                     chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel mLSTM (linear in S, quadratic only within a
    chunk).  q/k/v: (B,S,H,dh); log_i/log_f: (B,H,S).
    Returns (h: (B,S,H,dh) float32, end_state)."""
    B, S, H, dh = q.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)))
        # padded steps must not contribute: f=1 (log 0), i -> -inf
        log_i = log_i.at[:, :, S:].set(-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    G = Sp // L

    # reshape to (G, B, L, H, dh) / gates (G, B, H, L)
    qs = q.reshape(B, G, L, H, dh).transpose(1, 0, 2, 3, 4)
    ks_ = k.reshape(B, G, L, H, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, G, L, H, dh).transpose(1, 0, 2, 3, 4)
    lis = log_i.reshape(B, H, G, L).transpose(2, 0, 1, 3)
    lfs = log_f.reshape(B, H, G, L).transpose(2, 0, 1, 3)

    mask = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, xs):
        C, n, m_run = carry                    # (B,H,dh,dh),(B,H,dh),(B,H)
        qc, kc, vc, li, lf = xs
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        F = jnp.cumsum(lf, axis=-1)            # (B,H,L) in-chunk Σ log f
        # intra-chunk decay: D[t,s] = F_t - F_s + li_s (s <= t)
        Dl = F[..., :, None] - F[..., None, :] + li[..., None, :]
        Dl = jnp.where(mask[None, None], Dl, -jnp.inf)
        intra_max = jnp.max(Dl, axis=-1)       # (B,H,L)
        inter_log = F + m_run[..., None]       # carry-in weight per t
        m_t = jnp.maximum(intra_max, inter_log)            # (B,H,L)
        D = jnp.exp(Dl - m_t[..., None])                   # (B,H,L,L)
        w_inter = jnp.exp(inter_log - m_t)                 # (B,H,L)

        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, kc) * D
        num = jnp.einsum("bhqk,bkhd->bqhd", scores, vc) + \
            jnp.einsum("bhde,bqhd,bhq->bqhe", C, qc, w_inter)
        den = scores.sum(-1) + \
            jnp.einsum("bhd,bqhd,bhq->bhq", n, qc, w_inter)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t)) + 1e-6
        h = num / den.transpose(0, 2, 1)[..., None]        # (B,L,H,dh)

        # end-of-chunk state update
        Ftot = F[..., -1]                                   # (B,H)
        m_new = jnp.maximum(Ftot + m_run,
                            jnp.max(Ftot[..., None] - F + li, axis=-1))
        w_old = jnp.exp(Ftot + m_run - m_new)               # (B,H)
        w_s = jnp.exp(Ftot[..., None] - F + li - m_new[..., None])
        C = w_old[..., None, None] * C + \
            jnp.einsum("bkhd,bkhe,bhk->bhde", kc, vc, w_s)
        n = w_old[..., None] * n + jnp.einsum("bkhd,bhk->bhd", kc, w_s)
        return (C, n, m_new), h

    carry0 = (state["C"], state["n"], state["m"])
    (C, n, m), hs = jax.lax.scan(body, carry0, (qs, ks_, vs, lis, lfs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, dh)[:, :S]
    return h, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar memory, exponential gating, recurrent weights
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, proj_factor: float = 4.0 / 3.0
               ) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    dff = int(d * proj_factor)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype=cfg.dtype),   # i,f,z,o
        "r_gates": dense_init(ks[1], d, 4 * d, scale=1.0 / np.sqrt(d),
                              dtype=cfg.dtype),                    # recurrent
        "norm": norm_init(d, "rmsnorm", cfg.dtype),
        "w_up": dense_init(ks[2], d, dff, dtype=cfg.dtype),
        "w_down": dense_init(ks[3], dff, d, dtype=cfg.dtype),
    }


def make_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -1e30, jnp.float32),
            "h": z}


def _slstm_step(p, carry, xt):
    """One sLSTM timestep.  xt: (B, d)."""
    c, n, m, h = carry
    gates = (dense_apply(p["w_gates"], xt).astype(jnp.float32)
             + dense_apply(p["r_gates"], h.astype(xt.dtype))
             .astype(jnp.float32))
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)
    ip = jnp.exp(gi - m_new)
    fp = jnp.exp(log_f + m - m_new)
    c = fp * c + ip * jnp.tanh(gz)
    n = fp * n + ip
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h)


def apply_slstm(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[dict] = None):
    """x: (B, S, d) -> (y, new_state).  Sequential over S by design."""
    B, S, d = x.shape
    st = state or make_slstm_state(cfg, B)
    carry0 = (st["c"], st["n"], st["m"], st["h"])

    if S == 1:
        carry = _slstm_step(p, carry0, x[:, 0])
        hs = carry[3][:, None]
    else:
        def body(carry, xt):
            carry = _slstm_step(p, carry, xt)
            return carry, carry[3]
        carry, hsT = jax.lax.scan(body, carry0, jnp.swapaxes(x, 0, 1))
        hs = jnp.swapaxes(hsT, 0, 1)                  # (B, S, d)

    new_state = {"c": carry[0], "n": carry[1], "m": carry[2],
                 "h": carry[3]}
    y = norm_apply(p["norm"], hs.astype(x.dtype))
    y = dense_apply(p["w_down"], jax.nn.gelu(dense_apply(p["w_up"], y)))
    if state is None:
        new_state = None
    return y, new_state
