"""Mixture-of-Experts: token-choice top-k routing, GShard-style
capacity dispatch (einsum one-hot — expert-parallel friendly), shared
experts (DeepSeek-V2) and a parallel dense residual MLP (Arctic).

Tokens are routed in groups of ~TARGET_GROUP (GShard's standard trick):
the (tokens, experts, capacity) dispatch tensor exists only per group,
scanned over the sequence, so its footprint is bounded regardless of
batch x seq.  Expert FLOPs scale with routed capacity, NOT with E —
compiled FLOPs stay honest for the roofline table.

Expert weights carry a leading E dim that shards over the `model` mesh
axis (expert parallelism); XLA SPMD lowers dispatch/combine into
all-to-alls / reduce-scatters on that axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, MoEConfig
from .layers import dense_apply, dense_init, mlp_apply, mlp_init

TARGET_GROUP = 8192    # tokens routed together (global)

# §Perf knob: PartitionSpec for the dispatched expert activations
# xe/h/ye (E, C, d|ff).  None = let SPMD choose (baseline).  Setting
# ("model", "data", None) forces the capacity dim onto the data axis so
# the dispatch contraction lowers as reduce-scatter instead of
# all-reduce (launch/dryrun --moe-act-shard).
MOE_ACT_SPEC = None


def set_moe_act_spec(spec) -> None:
    globals()["MOE_ACT_SPEC"] = spec


def _constrain(x):
    if MOE_ACT_SPEC is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(*MOE_ACT_SPEC[: x.ndim])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_moe(key, cfg: ModelConfig) -> dict:
    mc: MoEConfig = cfg.moe
    d, ff, E = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = jax.random.split(key, 6)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, scale=0.02, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                   * scale).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                 * scale).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                   / np.sqrt(ff)).astype(cfg.dtype),
    }
    if mc.num_shared_experts > 0:
        shared_ff = mc.num_shared_experts * (mc.d_ff_residual or ff)
        p["shared"] = mlp_init(ks[4], d, shared_ff, cfg.act, cfg.dtype)
    if mc.dense_residual:
        res_ff = mc.d_ff_residual or ff
        p["residual"] = mlp_init(ks[5], d, res_ff, cfg.act, cfg.dtype)
    return p


def _capacity(T: int, E: int, top_k: int, factor: float) -> int:
    return max(1, int(math.ceil(T * top_k / E * factor)))


def _route_group(p: dict, xt: jnp.ndarray, cfg: ModelConfig
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Route one token group.  xt: (T, d) -> (y: (T, d), aux scalar)."""
    mc: MoEConfig = cfg.moe
    T, d = xt.shape
    E, k = mc.num_experts, mc.top_k
    C = _capacity(T, E, k, mc.capacity_factor)

    logits = dense_apply(p["router"], xt.astype(jnp.float32))    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)      # (T,k,E)
    # position of each (token, choice) within its expert's capacity
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = (pos * flat).sum(-1).reshape(T, k)                     # (T, k)
    keep = pos < C
    gate_vals = gate_vals * keep

    pos_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (T,k,C)
    disp = jnp.einsum("tke,tkc->tec", onehot * keep[..., None],
                      pos_onehot)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_onehot, gate_vals)

    xe = _constrain(
        jnp.einsum("tec,td->ecd", disp.astype(cfg.dtype), xt))   # (E,C,d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = _constrain(
        jnp.einsum("ecf,efd->ecd", _constrain(h), p["w_down"]))  # (E,C,d)
    y = jnp.einsum("tec,ecd->td", comb.astype(cfg.dtype), ye)    # (T,d)

    # load-balance auxiliary loss (Switch/GShard style)
    frac_tokens = onehot[:, 0].mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * mc.router_aux_weight
    return y, aux


def apply_moe(p: dict, x: jnp.ndarray, cfg: ModelConfig
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).  Groups of <=TARGET_GROUP tokens
    are routed per lax.scan step (sequence-chunked)."""
    B, S, d = x.shape
    chunk_s = max(1, min(S, TARGET_GROUP // B))
    while S % chunk_s:
        chunk_s -= 1          # shapes here are powers of two; loop is cheap
    n_chunks = S // chunk_s

    if n_chunks == 1:
        y, aux = _route_group(p, x.reshape(B * S, d), cfg)
        out = y.reshape(B, S, d)
    else:
        xs = x.reshape(B, n_chunks, chunk_s, d).transpose(1, 0, 2, 3)

        def body(_, xc):
            yc, aux_c = _route_group(p, xc.reshape(B * chunk_s, d), cfg)
            return None, (yc.reshape(B, chunk_s, d), aux_c)

        _, (ys, auxs) = jax.lax.scan(body, None, xs)
        out = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
        aux = jnp.mean(auxs)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, cfg.act)
    if "residual" in p:
        out = out + mlp_apply(p["residual"], x, cfg.act)
    return out, aux
