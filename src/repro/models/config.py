"""Unified model configuration covering all assigned architectures.

A model is described by a stack of typed blocks:
  prefix_kinds  — unrolled leading layers (e.g. deepseek's dense layer 0)
  scan_pattern  — the repeating group that is lax.scan-ed (HLO stays
                  O(|pattern|) regardless of depth)
  suffix        — num_layers - prefix - scanned remainder, unrolled,
                  taken as pattern[:r] (e.g. recurrentgemma's trailing
                  2 recurrent blocks).

Block kinds:
  dense        self-attn (GQA/RoPE/...) + dense MLP
  local        sliding-window self-attn + dense MLP
  moe          self-attn + routed MoE (+ optional shared experts)
  moe_residual self-attn + routed MoE with parallel dense residual MLP
  xattn        cross-attn (to frontend memory) + dense MLP
  rglru        RG-LRU recurrent block + dense MLP
  mlstm        mLSTM block (internal up-proj, no separate MLP)
  slstm        sLSTM block (internal up/down proj)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention.

    absorbed=True scores in latent space (q absorbed through W_uk,
    output combined through W_uv) — K/V are never expanded to
    (B, T, H, head_dim).  More score FLOPs (latent rank vs head_dim),
    far less memory traffic: the §Perf memory-bound variant."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128
    absorbed: bool = False


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0            # per-expert hidden dim
    num_shared_experts: int = 0     # deepseek: always-on shared experts
    dense_residual: bool = False    # arctic: parallel dense MLP
    d_ff_residual: int = 0          # hidden of the residual/shared MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    scan_pattern: tuple = ("dense",)
    prefix_kinds: tuple = ()

    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None        # sliding window (None = full)
    long_context_window: int = 4096     # window for the long_500k variant
    mla: Optional[MLAConfig] = None

    # mlp / norm
    act: str = "swiglu"                 # swiglu|gelu|geglu
    norm: str = "rmsnorm"               # rmsnorm|layernorm

    moe: Optional[MoEConfig] = None

    # enc-dec & stub frontends (DESIGN.md carve-out)
    encoder_layers: int = 0
    frontend: Optional[str] = None      # 'vision' | 'audio'
    num_frontend_tokens: int = 0

    # recurrent widths
    lru_width: int = 0                  # 0 -> d_model
    conv_width: int = 4

    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def decoder_layer_kinds(self) -> tuple[tuple, tuple, tuple]:
        """(prefix, scanned_groups × pattern, suffix) kind layout."""
        p = len(self.prefix_kinds)
        g = len(self.scan_pattern)
        body = self.num_layers - p
        n_groups = body // g
        r = body - n_groups * g
        return (tuple(self.prefix_kinds),
                tuple(self.scan_pattern) * 0 + tuple(self.scan_pattern),
                tuple(self.scan_pattern[:r]))

    def n_scan_groups(self) -> int:
        p = len(self.prefix_kinds)
        g = len(self.scan_pattern)
        return (self.num_layers - p) // g

    def with_overrides(self, **kw) -> "ModelConfig":
        from dataclasses import replace
        return replace(self, **kw)

    def validate(self) -> None:
        assert self.num_layers >= len(self.prefix_kinds)
        assert self.n_scan_groups() >= 0
        if self.moe is not None:
            assert any(k.startswith("moe") for k in
                       self.scan_pattern + self.prefix_kinds)
        if self.mla is None and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
