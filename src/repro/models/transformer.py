"""Model assembly: typed block stacks, lax.scan over repeating groups,
prefill/decode caches, encoder-decoder support, chunked LM loss.

The stack layout comes from ModelConfig: `prefix_kinds` (unrolled),
`scan_pattern` x n_groups (lax.scan over stacked params — HLO size is
O(|pattern|), critical for 100-layer models on 512 devices), and an
unrolled suffix for non-divisible depths (e.g. recurrentgemma 38 = 3x12
+ 2).

Modes:
  train    — full sequence, no cache, remat'd scan body
  prefill  — full sequence, fills decode caches, returns last logits
  decode   — one token through ring-buffer/recurrent caches
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import ModelConfig
from .layers import (dense_apply, dense_init, embed_apply, embed_init,
                     mlp_apply, mlp_init, norm_apply, norm_init)

LOSS_CHUNK = 512    # seq positions per LM-head chunk (bounds logits mem)


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("dense", "local", "enc"):
        return {
            "ln1": norm_init(d, cfg.norm, cfg.dtype),
            "attn": attn.init_self_attention(ks[0], cfg),
            "ln2": norm_init(d, cfg.norm, cfg.dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, cfg.dtype),
        }
    if kind in ("moe", "moe_residual"):
        return {
            "ln1": norm_init(d, cfg.norm, cfg.dtype),
            "attn": attn.init_self_attention(ks[0], cfg),
            "ln2": norm_init(d, cfg.norm, cfg.dtype),
            "moe": moe_mod.init_moe(ks[1], cfg),
        }
    if kind == "xattn":
        return {
            "ln1": norm_init(d, cfg.norm, cfg.dtype),
            "xattn": attn.init_cross_attention(ks[0], cfg),
            "gate_attn": jnp.zeros((), cfg.dtype),
            "ln2": norm_init(d, cfg.norm, cfg.dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, cfg.dtype),
            "gate_mlp": jnp.zeros((), cfg.dtype),
        }
    if kind == "dec":
        return {
            "ln1": norm_init(d, cfg.norm, cfg.dtype),
            "attn": attn.init_self_attention(ks[0], cfg),
            "ln2": norm_init(d, cfg.norm, cfg.dtype),
            "xattn": attn.init_cross_attention(ks[1], cfg),
            "ln3": norm_init(d, cfg.norm, cfg.dtype),
            "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.act, cfg.dtype),
        }
    if kind == "rglru":
        return {
            "ln1": norm_init(d, cfg.norm, cfg.dtype),
            "rglru": ssm.init_rglru(ks[0], cfg),
            "ln2": norm_init(d, cfg.norm, cfg.dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, cfg.dtype),
        }
    if kind == "mlstm":
        return {"ln": norm_init(d, cfg.norm, cfg.dtype),
                "core": ssm.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln": norm_init(d, cfg.norm, cfg.dtype),
                "core": ssm.init_slstm(ks[0], cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def make_block_cache(kind: str, cfg: ModelConfig, batch: int,
                     cache_len: int, window: Optional[int],
                     mem_len: int = 0):
    """Empty decode cache for one block (None for cacheless kinds)."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kind in ("dense", "moe", "moe_residual"):
        return attn.make_kv_cache(cfg, batch, cache_len, window)
    if kind == "local":
        return attn.make_kv_cache(cfg, batch, cache_len,
                                  window or cfg.window)
    if kind == "xattn":
        return {"k": jnp.zeros((batch, mem_len, KV, hd), cfg.dtype),
                "v": jnp.zeros((batch, mem_len, KV, hd), cfg.dtype)}
    if kind == "dec":
        return {
            "self": attn.make_kv_cache(cfg, batch, cache_len, window),
            "cross": {"k": jnp.zeros((batch, mem_len, KV, hd), cfg.dtype),
                      "v": jnp.zeros((batch, mem_len, KV, hd), cfg.dtype)},
        }
    if kind == "rglru":
        return ssm.make_rglru_state(cfg, batch)
    if kind == "mlstm":
        return ssm.make_mlstm_state(cfg, batch)
    if kind == "slstm":
        return ssm.make_slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------

def apply_block(kind: str, p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                cache=None, memory: Optional[jnp.ndarray] = None,
                window: Optional[int] = None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "local", "moe", "moe_residual"):
        win = window or cfg.window   # explicit override > config window
        h, new_c = attn.apply_self_attention(
            p["attn"], norm_apply(p["ln1"], x, cfg.norm), cfg,
            window=win, cache=cache)
        x = x + h
        h2 = norm_apply(p["ln2"], x, cfg.norm)
        if kind in ("moe", "moe_residual"):
            y, aux = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = mlp_apply(p["mlp"], h2, cfg.act)
        return x + y, new_c, aux

    if kind == "enc":   # bidirectional self-attention (no mask)
        h, _ = attn.apply_self_attention(
            p["attn"], norm_apply(p["ln1"], x, cfg.norm), cfg,
            window=None, cache=None)
        x = x + h
        y = mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg.norm), cfg.act)
        return x + y, None, aux

    if kind == "xattn":
        if cache is not None and memory is None:
            mem_kv = cache
            new_c = cache
        else:
            mem_kv = attn.precompute_cross_kv(p["xattn"], memory, cfg)
            new_c = mem_kv if cache is not None else None
        h = attn.apply_cross_attention(
            p["xattn"], norm_apply(p["ln1"], x, cfg.norm), cfg,
            mem_kv=mem_kv)
        x = x + jnp.tanh(p["gate_attn"]) * h
        y = mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg.norm), cfg.act)
        return x + jnp.tanh(p["gate_mlp"]) * y, new_c, aux

    if kind == "dec":
        c_self = cache["self"] if cache is not None else None
        h, new_self = attn.apply_self_attention(
            p["attn"], norm_apply(p["ln1"], x, cfg.norm), cfg,
            window=window, cache=c_self)
        x = x + h
        if cache is not None and memory is None:
            mem_kv = cache["cross"]
            new_cross = cache["cross"]
        else:
            mem_kv = attn.precompute_cross_kv(p["xattn"], memory, cfg)
            new_cross = mem_kv if cache is not None else None
        h = attn.apply_cross_attention(
            p["xattn"], norm_apply(p["ln2"], x, cfg.norm), cfg,
            mem_kv=mem_kv)
        x = x + h
        y = mlp_apply(p["mlp"], norm_apply(p["ln3"], x, cfg.norm), cfg.act)
        new_c = None
        if cache is not None:
            new_c = {"self": new_self, "cross": new_cross}
        return x + y, new_c, aux

    if kind == "rglru":
        h, new_c = ssm.apply_rglru(
            p["rglru"], norm_apply(p["ln1"], x, cfg.norm), cfg, state=cache)
        x = x + h
        y = mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg.norm), cfg.act)
        return x + y, new_c, aux

    if kind == "mlstm":
        h, new_c = ssm.apply_mlstm(
            p["core"], norm_apply(p["ln"], x, cfg.norm), cfg, state=cache)
        return x + h, new_c, aux

    if kind == "slstm":
        h, new_c = ssm.apply_slstm(
            p["core"], norm_apply(p["ln"], x, cfg.norm), cfg, state=cache)
        return x + h, new_c, aux

    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------

def _group_init(key, pattern: tuple, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, len(pattern))
    return {f"b{j}": init_block(ks[j], kind, cfg)
            for j, kind in enumerate(pattern)}


def init_decoder_stack(key, cfg: ModelConfig) -> dict:
    prefix, pattern, suffix = cfg.prefix_kinds, cfg.scan_pattern, \
        cfg.decoder_layer_kinds()[2]
    G = cfg.n_scan_groups()
    kp, ksc, ksu = jax.random.split(key, 3)
    out: dict = {}
    out["prefix"] = [init_block(jax.random.fold_in(kp, i), k, cfg)
                     for i, k in enumerate(prefix)]
    if G > 0:
        groups = [_group_init(jax.random.fold_in(ksc, g), pattern, cfg)
                  for g in range(G)]
        out["scan"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *groups)
    else:
        out["scan"] = {}
    out["suffix"] = [init_block(jax.random.fold_in(ksu, i), k, cfg)
                     for i, k in enumerate(suffix)]
    return out


def make_decoder_cache(cfg: ModelConfig, batch: int, cache_len: int,
                       window: Optional[int], mem_len: int = 0) -> dict:
    prefix, pattern, suffix = cfg.prefix_kinds, cfg.scan_pattern, \
        cfg.decoder_layer_kinds()[2]
    G = cfg.n_scan_groups()

    def one(kind):
        return make_block_cache(kind, cfg, batch, cache_len, window,
                                mem_len)

    cache: dict = {
        "prefix": [one(k) for k in prefix],
        "suffix": [one(k) for k in suffix],
    }
    if G > 0:
        group = {f"b{j}": one(k) for j, k in enumerate(pattern)}
        cache["scan"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape).copy(),
            group)
    else:
        cache["scan"] = {}
    return cache


def apply_decoder_stack(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                        cache: Optional[dict] = None,
                        memory: Optional[jnp.ndarray] = None,
                        window: Optional[int] = None,
                        remat: bool = False):
    """Returns (x, new_cache, aux_total)."""
    prefix, pattern, suffix = cfg.prefix_kinds, cfg.scan_pattern, \
        cfg.decoder_layer_kinds()[2]
    G = cfg.n_scan_groups()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {"prefix": [], "suffix": [], "scan": {}}

    for i, kind in enumerate(prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = apply_block(kind, params["prefix"][i], x, cfg,
                                 cache=c, memory=memory, window=window)
        new_cache["prefix"].append(nc)
        aux_total += aux

    if G > 0:
        has_cache = cache is not None

        def body(carry, xs):
            xx = carry
            if has_cache:
                p_g, c_g = xs
            else:
                p_g, c_g = xs, None
            new_cs = {}
            aux_g = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(pattern):
                cj = c_g[f"b{j}"] if has_cache else None
                xx, nc, aux = apply_block(kind, p_g[f"b{j}"], xx, cfg,
                                          cache=cj, memory=memory,
                                          window=window)
                new_cs[f"b{j}"] = nc if has_cache else jnp.zeros(())
                aux_g = aux_g + aux
            return xx, (new_cs, aux_g)

        if remat:
            body = jax.checkpoint(body)
        xs = (params["scan"], cache["scan"]) if has_cache \
            else params["scan"]
        x, (scan_caches, auxs) = jax.lax.scan(body, x, xs)
        if has_cache:
            new_cache["scan"] = scan_caches
        aux_total += jnp.sum(auxs)

    for i, kind in enumerate(suffix):
        c = cache["suffix"][i] if cache is not None else None
        x, nc, aux = apply_block(kind, params["suffix"][i], x, cfg,
                                 cache=c, memory=memory, window=window)
        new_cache["suffix"].append(nc)
        aux_total += aux

    if cache is None:
        new_cache = None
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# full language model
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    p: dict = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                            cfg.dtype),
        "decoder": init_decoder_stack(ks[1], cfg),
        "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                  dtype=cfg.dtype)
    if cfg.encoder_layers > 0:
        enc_cfg = cfg.with_overrides(
            num_layers=cfg.encoder_layers, scan_pattern=("enc",),
            prefix_kinds=(), moe=None, mla=None)
        p["encoder"] = init_decoder_stack(ks[3], enc_cfg)
        p["enc_norm"] = norm_init(cfg.d_model, cfg.norm, cfg.dtype)
    return p


def run_encoder(params: dict, memory_emb: jnp.ndarray, cfg: ModelConfig
                ) -> jnp.ndarray:
    """Bidirectional encoder over (stub-)frontend embeddings."""
    enc_cfg = cfg.with_overrides(
        num_layers=cfg.encoder_layers, scan_pattern=("enc",),
        prefix_kinds=(), moe=None, mla=None)
    x, _, _ = apply_decoder_stack(params["encoder"], memory_emb, enc_cfg)
    return norm_apply(params["enc_norm"], x, cfg.norm)


def _lm_logits(params: dict, h: jnp.ndarray, cfg: ModelConfig
               ) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return h @ params["embed"]["table"].T
    return dense_apply(params["lm_head"], h)


def _memory_states(params, batch, cfg):
    mem = batch.get("memory")
    if mem is None:
        return None
    if cfg.encoder_layers > 0:        # audio enc-dec: run real encoder
        return run_encoder(params, mem, cfg)
    return mem                        # VLM: projector output, used as-is


def forward_hidden(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, *,
                   memory=None, window=None, remat=False):
    x = embed_apply(params["embed"], tokens)
    x, _, aux = apply_decoder_stack(params["decoder"], x, cfg,
                                    memory=memory, window=window,
                                    remat=remat)
    return norm_apply(params["final_norm"], x, cfg.norm), aux


def lm_loss(params: dict, batch: dict, cfg: ModelConfig, *,
            window: Optional[int] = None, remat: bool = True):
    """Causal LM loss; LM head applied in seq chunks so (B,S,V) logits
    never materialize (V up to 256k)."""
    tokens, labels = batch["tokens"], batch["labels"]
    memory = _memory_states(params, batch, cfg)
    h, aux = forward_hidden(params, tokens, cfg, memory=memory,
                            window=window, remat=remat)
    B, S, d = h.shape
    chunk = min(LOSS_CHUNK, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-1)
    n_chunks = h.shape[1] // chunk
    hs = h.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        hc, lc = xs
        logits = _lm_logits(params, hc, cfg).astype(jnp.float32)
        # mask out vocab padding columns
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vmask[None, None], logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = lc >= 0
        lsafe = jnp.maximum(lc, 0)
        nll = -jnp.take_along_axis(logp, lsafe[..., None], axis=-1)[..., 0]
        loss_sum = jnp.sum(nll * valid)
        count = jnp.sum(valid)
        return carry, (loss_sum, count)

    _, (sums, counts) = jax.lax.scan(body, None, (hs, ls))
    loss = jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1)
    return loss + aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, *,
            cache_len: int, window: Optional[int] = None,
            memory=None):
    """Run the prompt, fill caches, return (last_logits, cache)."""
    B, S = tokens.shape
    mem_states = None
    mem_len = 0
    if memory is not None:
        mem_states = (run_encoder(params, memory, cfg)
                      if cfg.encoder_layers > 0 else memory)
        mem_len = mem_states.shape[1]
    cache = make_decoder_cache(cfg, B, cache_len, window, mem_len)
    x = embed_apply(params["embed"], tokens)
    x, cache, _ = apply_decoder_stack(params["decoder"], x, cfg,
                                      cache=cache, memory=mem_states,
                                      window=window)
    h = norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
    return _lm_logits(params, h, cfg), cache


def decode_step(params: dict, token: jnp.ndarray, cache: dict,
                cfg: ModelConfig, *, window: Optional[int] = None):
    """One-token decode: token (B, 1) int32 -> (logits (B,1,V), cache)."""
    x = embed_apply(params["embed"], token)
    x, cache, _ = apply_decoder_stack(params["decoder"], x, cfg,
                                      cache=cache, memory=None,
                                      window=window)
    h = norm_apply(params["final_norm"], x, cfg.norm)
    return _lm_logits(params, h, cfg), cache
