"""Model zoo: unified-config transformer family + paper CNN."""
from . import attention, cnn, layers, moe, ssm, transformer
from .config import MLAConfig, ModelConfig, MoEConfig

__all__ = ["attention", "cnn", "layers", "moe", "ssm", "transformer",
           "MLAConfig", "ModelConfig", "MoEConfig"]
