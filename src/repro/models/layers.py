"""Shared neural building blocks (functional init/apply style).

Parameters are plain nested dicts of jnp arrays so they flow through
FedNC packetization, the checkpointing layer, and pjit sharding rules
without adapters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: Optional[float] = None, dtype=jnp.bfloat16) -> dict:
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.bfloat16) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: dict, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def mlp_init(key, d_model: int, d_ff: int, act: str,
             dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, d_model, dtype=dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff, dtype=dtype)
        p["up"] = dense_init(k3, d_model, d_ff, dtype=dtype)
    else:  # gelu
        p["up"] = dense_init(k1, d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(dense_apply(p["gate"], x)) * dense_apply(p["up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(dense_apply(p["gate"], x)) * dense_apply(p["up"], x)
    elif act == "gelu":
        h = jax.nn.gelu(dense_apply(p["up"], x))
    else:
        raise ValueError(act)
    return dense_apply(p["down"], h)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_apply(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
