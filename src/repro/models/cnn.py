"""The paper's local model (§IV-A.1): a 6-conv-layer CNN with batch
normalization and max pooling, for 10-class 32x32x3 image
classification.  Functional init/apply with explicit BN state — the BN
running statistics travel inside the FedNC packets exactly like
weights (they are part of w_k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CHANNELS = (32, 32, 64, 64, 128, 128)


def init_cnn(key, *, num_classes: int = 10, in_channels: int = 3,
             image_size: int = 32, dtype=jnp.float32) -> dict:
    params: dict = {}
    c_in = in_channels
    ks = jax.random.split(key, len(CHANNELS) + 1)
    for i, c_out in enumerate(CHANNELS):
        fan_in = 3 * 3 * c_in
        params[f"conv{i}"] = {
            "w": (jax.random.normal(ks[i], (3, 3, c_in, c_out), jnp.float32)
                  * np.sqrt(2.0 / fan_in)).astype(dtype),
            "b": jnp.zeros((c_out,), dtype),
            "bn_scale": jnp.ones((c_out,), dtype),
            "bn_bias": jnp.zeros((c_out,), dtype),
            # BN running stats live in params so FedNC ships them too
            "bn_mean": jnp.zeros((c_out,), jnp.float32),
            "bn_var": jnp.ones((c_out,), jnp.float32),
        }
        c_in = c_out
    # 3 maxpools of stride 2: 32 -> 16 -> 8 -> 4
    feat = (image_size // 8) ** 2 * CHANNELS[-1]
    params["fc"] = {
        "w": (jax.random.normal(ks[-1], (feat, num_classes), jnp.float32)
              / np.sqrt(feat)).astype(dtype),
        "b": jnp.zeros((num_classes,), dtype),
    }
    return params


def _conv_bn(p: dict, x: jnp.ndarray, train: bool, momentum: float = 0.9
             ) -> tuple[jnp.ndarray, dict]:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + p["b"]
    if train:
        mu = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
        new_p = dict(p)
        new_p["bn_mean"] = momentum * p["bn_mean"] + (1 - momentum) * mu
        new_p["bn_var"] = momentum * p["bn_var"] + (1 - momentum) * var
    else:
        mu, var = p["bn_mean"], p["bn_var"]
        new_p = p
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["bn_scale"] + p["bn_bias"]
    return jax.nn.relu(y), new_p


def _maxpool(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply_cnn(params: dict, x: jnp.ndarray, *, train: bool = False
              ) -> tuple[jnp.ndarray, dict]:
    """x: (B, H, W, C) -> (logits, updated_params_with_bn_stats)."""
    new_params = dict(params)
    for i in range(len(CHANNELS)):
        x, new_params[f"conv{i}"] = _conv_bn(params[f"conv{i}"], x, train)
        if i % 2 == 1:
            x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    logits = x @ params["fc"]["w"] + params["fc"]["b"]
    return logits, new_params


def cnn_loss(params: dict, batch: tuple, *, train: bool = True):
    """Cross-entropy loss; aux = updated params (BN stats)."""
    x, y = batch
    logits, new_params = apply_cnn(params, x, train=train)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return loss, new_params


def merge_bn_stats(params: dict, new_params: dict) -> dict:
    """Carry BN running statistics from a train-mode apply back into the
    parameter tree (LocalTrainer.state_merge hook)."""
    out = dict(params)
    for i in range(len(CHANNELS)):
        conv = dict(out[f"conv{i}"])
        conv["bn_mean"] = new_params[f"conv{i}"]["bn_mean"]
        conv["bn_var"] = new_params[f"conv{i}"]["bn_var"]
        out[f"conv{i}"] = conv
    return out


def cnn_accuracy(params: dict, images, labels, batch: int = 512) -> float:
    """Eval accuracy (running BN stats)."""
    correct = 0
    n = len(labels)
    for i in range(0, n, batch):
        logits, _ = apply_cnn(params, jnp.asarray(images[i:i + batch]),
                              train=False)
        pred = jnp.argmax(logits, axis=-1)
        correct += int((pred == jnp.asarray(labels[i:i + batch])).sum())
    return correct / n
