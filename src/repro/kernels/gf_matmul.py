"""Pallas TPU kernel: GF(2^s) coded matmul  C = A · P.

This is FedNC's compute hot-spot: every round the K client packets
(K x L symbol matrix P, L = model bytes — millions) are mixed by the
(n x K) coding matrix A, and decode applies A^-1 the same way.

TPU adaptation (DESIGN.md §3a): GPU RLNC codes use 256-entry log/exp
lookup tables, but scattered gathers are the wrong shape for the TPU
VPU.  Instead we compute the field product as a **carry-less multiply +
polynomial reduction**, which is pure bitwise/shift vector arithmetic:

    clmul(a, b) = XOR_{i: b_i=1} (a << i)            (degree <= 2s-2)
    a *_GF b    = clmul(a, b) mod primitive_poly(s)

Both loops are static (s <= 8 iterations each) and fully vectorized
over the packet block, so the kernel is a streaming VPU workload tiled
for VMEM: A (n x K) stays resident; P/C move through HBM->VMEM in
(K x BLOCK_L) / (n x BLOCK_L) tiles.  The MXU is deliberately unused —
GF(2^s) has no systolic mapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gf import PRIMITIVE_POLY

# Symbols are uint8; compute in int32 lanes (native VPU width).
_COMPUTE_DTYPE = jnp.int32

DEFAULT_BLOCK_L = 2048  # lane-dim tile; multiple of 128


def _gf_mul_vec(a, b, s: int):
    """Vectorized GF(2^s) product of int32 arrays holding s-bit values."""
    poly = PRIMITIVE_POLY[s]
    acc = jnp.zeros_like(a)
    # carry-less multiply: acc = XOR_i (a << i) where bit i of b is set
    for i in range(s):
        bit = (b >> i) & 1
        acc = acc ^ ((a << i) * bit)
    # reduce modulo the primitive polynomial (degree s)
    for i in range(2 * s - 2, s - 1, -1):
        bit = (acc >> i) & 1
        acc = acc ^ ((poly << (i - s)) * bit)
    return acc


def _kernel(a_ref, p_ref, c_ref, *, s: int, K: int):
    A = a_ref[...].astype(_COMPUTE_DTYPE)          # (n, K)
    P = p_ref[...].astype(_COMPUTE_DTYPE)          # (K, bL)
    n = A.shape[0]
    acc = jnp.zeros((n, P.shape[1]), _COMPUTE_DTYPE)
    for k in range(K):                             # static, K small
        coeff = A[:, k][:, None]                   # (n, 1)
        acc = acc ^ _gf_mul_vec(
            jnp.broadcast_to(coeff, acc.shape),
            jnp.broadcast_to(P[k][None, :], acc.shape),
            s,
        )
    c_ref[...] = acc.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("s", "block_l", "interpret")
)
def gf_matmul_pallas(
    A: jnp.ndarray,
    P: jnp.ndarray,
    *,
    s: int = 8,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = A·P over GF(2^s) via the Pallas kernel.

    A: (n, K) uint8 coding matrix.  P: (K, L) uint8 symbol packets.
    Returns (n, L) uint8.  `interpret=True` executes on CPU for
    validation; on a real TPU pass interpret=False.
    """
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    n, K = A.shape
    K2, L = P.shape
    if K2 != K:
        raise ValueError(f"A is (n,{K}) but P is ({K2},L)")
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)

    # pad the lane dim to the tile size
    pad = (-L) % block_l
    Pp = jnp.pad(P, ((0, 0), (0, pad)))
    Lp = L + pad
    grid = (Lp // block_l,)

    out = pl.pallas_call(
        functools.partial(_kernel, s=s, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, K), lambda m: (0, 0)),        # A resident
            pl.BlockSpec((K, block_l), lambda m: (0, m)),  # P tile
        ],
        out_specs=pl.BlockSpec((n, block_l), lambda m: (0, m)),
        out_shape=jax.ShapeDtypeStruct((n, Lp), jnp.uint8),
        interpret=interpret,
    )(A, Pp)
    return out[:, :L]
