"""Pallas TPU kernel: GF(2^s) coded matmul  C = A · P.

This is FedNC's compute hot-spot: every round the K client packets
(K x L symbol matrix P, L = model bytes — millions) are mixed by the
(n x K) coding matrix A, and decode applies A^-1 the same way.

TPU adaptation (DESIGN.md §3a): GPU RLNC codes use 256-entry log/exp
lookup tables, but scattered gathers are the wrong shape for the TPU
VPU.  Instead we compute the field product as a **carry-less multiply +
polynomial reduction**, which is pure bitwise/shift vector arithmetic:

    clmul(a, b) = XOR_{i: b_i=1} (a << i)            (degree <= 2s-2)
    a *_GF b    = clmul(a, b) mod primitive_poly(s)

Both loops are static (s <= 8 iterations each) and fully vectorized
over the packet block, so the kernel is a streaming VPU workload tiled
for VMEM: A (n x K) stays resident; P/C move through HBM->VMEM in
(K x BLOCK_L) / (n x BLOCK_L) tiles.  The MXU is deliberately unused —
GF(2^s) has no systolic mapping.

Two kernel variants live here (both registered with the engine's
kernel registry, repro.engine.registry):

* `gf_matmul_pallas`        — one uint8 symbol per int32 compute lane
                              (the original formulation).
* `gf_matmul_pallas_packed` — **lane-packed**: 4 uint8 symbols ride in
                              each int32 lane (one per byte).  The
                              product is computed by a Russian-peasant
                              ladder: acc ^= (P·x^i)·bit_i(a), where
                              the "times x" step (`_xtime_packed`) is a
                              masked shift + per-byte polynomial
                              reduction that never crosses byte lanes.
                              4x fewer vector ops per symbol.

A third variant, `gf_matmul_pallas_packed_seeded`, takes (N,) uint32
row seeds instead of the (N, K) coding matrix and regenerates its
coefficient tile *inside* the kernel with the counter-based Threefry
stream (`repro.core.seeds`) — the coding matrix never exists in HBM;
only 4 bytes per output row cross the memory (and network) boundary.
The deliberate non-choice: `pltpu.prng_random_bits` would be faster
on TPU but is not bit-reproducible across backends, and the seeded
family's contract is byte-identical rows everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gf import PRIMITIVE_POLY

# Symbols are uint8; compute in int32 lanes (native VPU width).
_COMPUTE_DTYPE = jnp.int32

DEFAULT_BLOCK_L = 2048  # lane-dim tile; multiple of 128


def _gf_mul_vec(a, b, s: int):
    """Vectorized GF(2^s) product of int32 arrays holding s-bit values."""
    poly = PRIMITIVE_POLY[s]
    acc = jnp.zeros_like(a)
    # carry-less multiply: acc = XOR_i (a << i) where bit i of b is set
    for i in range(s):
        bit = (b >> i) & 1
        acc = acc ^ ((a << i) * bit)
    # reduce modulo the primitive polynomial (degree s)
    for i in range(2 * s - 2, s - 1, -1):
        bit = (acc >> i) & 1
        acc = acc ^ ((poly << (i - s)) * bit)
    return acc


def _kernel(a_ref, p_ref, c_ref, *, s: int, K: int):
    A = a_ref[...].astype(_COMPUTE_DTYPE)          # (n, K)
    P = p_ref[...].astype(_COMPUTE_DTYPE)          # (K, bL)
    n = A.shape[0]
    acc = jnp.zeros((n, P.shape[1]), _COMPUTE_DTYPE)
    for k in range(K):                             # static, K small
        coeff = A[:, k][:, None]                   # (n, 1)
        acc = acc ^ _gf_mul_vec(
            jnp.broadcast_to(coeff, acc.shape),
            jnp.broadcast_to(P[k][None, :], acc.shape),
            s,
        )
    c_ref[...] = acc.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("s", "block_l", "interpret")
)
def gf_matmul_pallas(
    A: jnp.ndarray,
    P: jnp.ndarray,
    *,
    s: int = 8,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = A·P over GF(2^s) via the Pallas kernel.

    A: (n, K) uint8 coding matrix.  P: (K, L) uint8 symbol packets.
    Returns (n, L) uint8.  `interpret=True` executes on CPU for
    validation; on a real TPU pass interpret=False.
    """
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    n, K = A.shape
    K2, L = P.shape
    if K2 != K:
        raise ValueError(f"A is (n,{K}) but P is ({K2},L)")
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)

    # pad the lane dim to the tile size
    pad = (-L) % block_l
    Pp = jnp.pad(P, ((0, 0), (0, pad)))
    Lp = L + pad
    grid = (Lp // block_l,)

    out = pl.pallas_call(
        functools.partial(_kernel, s=s, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, K), lambda m: (0, 0)),        # A resident
            pl.BlockSpec((K, block_l), lambda m: (0, m)),  # P tile
        ],
        out_specs=pl.BlockSpec((n, block_l), lambda m: (0, m)),
        out_shape=jax.ShapeDtypeStruct((n, Lp), jnp.uint8),
        interpret=interpret,
    )(A, Pp)
    return out[:, :L]


# ---------------------------------------------------------------------------
# int32 lane packing: 4 uint8 symbols per compute lane
# ---------------------------------------------------------------------------
#
# Layout: the L symbol lanes (one uint8 each, value < 2^s) are bitcast
# four-at-a-time into int32 words, one symbol per byte.  All field
# arithmetic below is byte-parallel: shifts are masked so no bit ever
# crosses a byte boundary, and the polynomial reduction is applied per
# byte via a 0x01-replicated indicator multiply.

_ONE_MASK = 0x01010101   # bit 0 of every byte lane
LANES_PER_WORD = 4

DEFAULT_BLOCK_W = 512    # packed-word tile (= 2048 symbols); mult of 128


def _xtime_packed(w, s: int):
    """Multiply each packed s-bit symbol by x (the field generator).

    w: int32 array, 4 symbols per word (one per byte, each < 2^s).
    Equivalent to `mul(w, 2)` in GF(2^s), byte-parallel:
      * drop each symbol's top bit (degree s-1), shift left one;
      * XOR the reduced polynomial into bytes whose top bit was set.
    The indicator `hi` is extracted with a logical-safe mask, so int32
    arithmetic right-shift smear cannot leak across lanes.
    """
    poly_red = PRIMITIVE_POLY[s] ^ (1 << s)           # poly minus x^s
    low_mask = ((1 << (s - 1)) - 1) * _ONE_MASK
    hi = (w >> (s - 1)) & _ONE_MASK
    return ((w & low_mask) << 1) ^ (hi * poly_red)


def pack_lanes(P: jnp.ndarray) -> jnp.ndarray:
    """(…, L) uint8 symbols -> (…, ceil(L/4)) int32 packed words."""
    P = jnp.asarray(P, jnp.uint8)
    L = P.shape[-1]
    pad = (-L) % LANES_PER_WORD
    if pad:
        P = jnp.pad(P, [(0, 0)] * (P.ndim - 1) + [(0, pad)])
    grouped = P.reshape(*P.shape[:-1], -1, LANES_PER_WORD)
    return jax.lax.bitcast_convert_type(grouped, jnp.int32)


def unpack_lanes(W: jnp.ndarray, L: int) -> jnp.ndarray:
    """Inverse of :func:`pack_lanes`: (…, Lw) int32 -> (…, L) uint8."""
    b = jax.lax.bitcast_convert_type(W, jnp.uint8)     # (…, Lw, 4)
    return b.reshape(*W.shape[:-1], -1)[..., :L]


def _packed_kernel(a_ref, p_ref, c_ref, *, s: int, K: int):
    A = a_ref[...].astype(jnp.int32)                   # (n, K)
    W = p_ref[...]                                     # (K, bW) int32
    n = A.shape[0]
    acc = jnp.zeros((n, W.shape[1]), jnp.int32)
    for k in range(K):                                 # static, K small
        w = W[k][None, :]                              # P_k · x^i ladder
        coeff = A[:, k][:, None]                       # (n, 1)
        for i in range(s):
            bit = (coeff >> i) & 1
            acc = acc ^ (w * bit)
            if i + 1 < s:
                w = _xtime_packed(w, s)
    c_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("s", "block_w", "interpret")
)
def gf_matmul_pallas_packed(
    A: jnp.ndarray,
    P: jnp.ndarray,
    *,
    s: int = 8,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = True,
) -> jnp.ndarray:
    """Lane-packed C = A·P over GF(2^s): 4 symbols per int32 lane.

    Same contract as :func:`gf_matmul_pallas` (A (n,K) uint8, P (K,L)
    uint8 -> (n,L) uint8) but the kernel consumes P bitcast to int32
    words, so each VPU lane carries four symbols.  The per-k inner
    ladder shares the x^i multiples of the packet row across all n
    output rows.
    """
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    n, K = A.shape
    K2, L = P.shape
    if K2 != K:
        raise ValueError(f"A is (n,{K}) but P is ({K2},L)")
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)

    W = pack_lanes(P)                                  # (K, Lw)
    Lw = W.shape[1]
    pad = (-Lw) % block_w
    Wp = jnp.pad(W, ((0, 0), (0, pad)))
    Lwp = Lw + pad
    grid = (Lwp // block_w,)

    out = pl.pallas_call(
        functools.partial(_packed_kernel, s=s, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, K), lambda m: (0, 0)),        # A resident
            pl.BlockSpec((K, block_w), lambda m: (0, m)),  # packed tile
        ],
        out_specs=pl.BlockSpec((n, block_w), lambda m: (0, m)),
        out_shape=jax.ShapeDtypeStruct((n, Lwp), jnp.int32),
        interpret=interpret,
    )(A, Wp)
    return unpack_lanes(out[:, :Lw], L)


# ---------------------------------------------------------------------------
# seeded variant: coefficient tile regenerated in-kernel from uint32 seeds
# ---------------------------------------------------------------------------

def _packed_seeded_kernel(seed_ref, p_ref, c_ref, *, s: int, K: int):
    """Lane-packed ladder with the A tile derived from row seeds.

    `seed_ref` holds the (n, 1) uint32 seeds; the Threefry counter
    stream rebuilds all K coefficients per row in-register before the
    ladder runs — the (n, K) matrix never touches HBM.  Same field
    math as `_packed_kernel`, property-tested bit-identical.
    """
    from repro.core.seeds import COEFFS_PER_WORD, coeff_words

    seeds = seed_ref[...][:, 0]                        # (n,) uint32
    W = p_ref[...]                                     # (K, bW) int32
    n = seeds.shape[0]
    words = coeff_words(seeds, -(-K // COEFFS_PER_WORD))
    mask = jnp.int32((1 << s) - 1)
    acc = jnp.zeros((n, W.shape[1]), jnp.int32)
    for k in range(K):                                 # static, K small
        w = W[k][None, :]                              # P_k · x^i ladder
        byte = (words[:, k // COEFFS_PER_WORD]
                >> jnp.uint32(8 * (k % COEFFS_PER_WORD)))
        coeff = (byte.astype(jnp.int32) & mask)[:, None]
        for i in range(s):
            bit = (coeff >> i) & 1
            acc = acc ^ (w * bit)
            if i + 1 < s:
                w = _xtime_packed(w, s)
    c_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("s", "block_w", "interpret")
)
def gf_matmul_pallas_packed_seeded(
    seeds: jnp.ndarray,
    P: jnp.ndarray,
    *,
    s: int = 8,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = True,
) -> jnp.ndarray:
    """Seeded lane-packed C = rows(seeds)·P over GF(2^s).

    `seeds`: (n,) uint32 row seeds; `P`: (K, L) uint8 symbols.  Row i
    of the implicit coding matrix is `repro.core.seeds.expand_rows`
    of seed i — regenerated inside each grid step, never materialized
    as a kernel operand — and the result is bit-identical to
    ``gf_matmul_pallas_packed(expand_rows(seeds, K, s), P)``.
    """
    seeds = jnp.asarray(seeds, jnp.uint32)
    P = jnp.asarray(P, jnp.uint8)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be (n,), got {seeds.shape}")
    n = seeds.shape[0]
    K, L = P.shape
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)

    W = pack_lanes(P)                                  # (K, Lw)
    Lw = W.shape[1]
    pad = (-Lw) % block_w
    Wp = jnp.pad(W, ((0, 0), (0, pad)))
    Lwp = Lw + pad
    grid = (Lwp // block_w,)

    out = pl.pallas_call(
        functools.partial(_packed_seeded_kernel, s=s, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda m: (0, 0)),        # seeds resident
            pl.BlockSpec((K, block_w), lambda m: (0, m)),  # packed tile
        ],
        out_specs=pl.BlockSpec((n, block_w), lambda m: (0, m)),
        out_shape=jax.ShapeDtypeStruct((n, Lwp), jnp.int32),
        interpret=interpret,
    )(seeds[:, None], Wp)
    return unpack_lanes(out[:, :Lw], L)
