"""Pallas TPU kernels for FedNC's GF(2^s) coding hot-spot.

gf_matmul.py — GF(2^s) coded matmul: unpacked clmul formulation plus
               the int32 lane-packed variant (4 symbols/lane), both
               VMEM-tiled
gf2_xor.py   — GF(2) masked-XOR fast path (s=1)
ops.py       — compatibility facade over the engine kernel registry
               (repro.engine.registry owns backend dispatch)
ref.py       — pure-jnp formulations: table-based oracle + interpret-free
               clmul/lane-packed mirrors of the kernels
"""
from . import ops, ref

__all__ = ["ops", "ref"]
