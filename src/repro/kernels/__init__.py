"""Pallas TPU kernels for FedNC's GF(2^s) coding hot-spot.

gf_matmul.py — GF(2^s) coded matmul (clmul formulation, VMEM-tiled)
gf2_xor.py   — GF(2) masked-XOR fast path (s=1)
ops.py       — jitted dispatch wrappers (jnp oracle on CPU, Pallas on TPU)
ref.py       — pure-jnp oracles (table-based; independent formulation)
"""
from . import ops, ref
