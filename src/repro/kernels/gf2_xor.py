"""Pallas TPU kernel: GF(2) coded combine (the s=1 fast path).

For s=1 the coding coefficients are bits and the field product
degenerates to a masked XOR: C[i] = XOR_{k : A[i,k]=1} P[k].  The
combination acts on whole bytes (bit-planes mix independently), so the
kernel streams the raw uint8 packet matrix — no symbol splitting, no
multiplies.  This is the cheapest FedNC configuration the paper
evaluates (Table I row s=1) and is bandwidth-bound by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_L = 4096  # bytes per tile; multiple of 128


def _kernel(a_ref, p_ref, c_ref, *, K: int):
    A = a_ref[...].astype(jnp.int32)      # (n, K) in {0,1}
    P = p_ref[...].astype(jnp.int32)      # (K, bL)
    n = A.shape[0]
    acc = jnp.zeros((n, P.shape[1]), jnp.int32)
    for k in range(K):
        mask = (A[:, k] & 1)[:, None]     # (n, 1)
        acc = acc ^ (P[k][None, :] * mask)
    c_ref[...] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def gf2_matmul_pallas(
    A: jnp.ndarray,
    P: jnp.ndarray,
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = A·P over GF(2).  A: (n, K) {0,1} uint8; P: (K, L) uint8 bytes."""
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    n, K = A.shape
    K2, L = P.shape
    if K2 != K:
        raise ValueError(f"A is (n,{K}) but P is ({K2},L)")
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)

    pad = (-L) % block_l
    Pp = jnp.pad(P, ((0, 0), (0, pad)))
    Lp = L + pad
    grid = (Lp // block_l,)

    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, K), lambda m: (0, 0)),
            pl.BlockSpec((K, block_l), lambda m: (0, m)),
        ],
        out_specs=pl.BlockSpec((n, block_l), lambda m: (0, m)),
        out_shape=jax.ShapeDtypeStruct((n, Lp), jnp.uint8),
        interpret=interpret,
    )(A, Pp)
    return out[:, :L]
