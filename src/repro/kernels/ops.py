"""Jitted public wrappers around the GF coding kernels.

Backend choice is owned by the engine kernel registry
(repro.engine.registry) — this module is a thin compatibility facade
over it.  The legacy `impl` strings map 1:1 onto registry names:

  * 'jnp'    — table-based jnp oracle
  * 'pallas' — the Pallas TPU kernel (interpret=True on CPU)
  * 'auto'   — registry default: lane-packed Pallas on TPU, lane-packed
               jnp elsewhere

plus the newer registry names ('jnp_clmul', 'jnp_packed',
'pallas_packed', custom registrations) which pass straight through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .gf2_xor import gf2_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gf_matmul(A, P, *, s: int = 8, impl: str = "auto") -> jnp.ndarray:
    """C = A·P over GF(2^s); dispatches through the engine registry."""
    from repro.engine.registry import gf_matmul as registry_matmul
    return registry_matmul(A, P, s=s, kernel=impl)


def gf2_combine(A, P, *, impl: str = "auto") -> jnp.ndarray:
    """GF(2) byte-stream combine (s=1 fast path, coefficient bits)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return ref.gf2_matmul_ref(A, P)
    if impl == "pallas":
        return gf2_matmul_pallas(A, P, interpret=not _on_tpu())
    raise ValueError(f"unknown impl {impl!r}")
