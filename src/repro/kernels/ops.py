"""Jitted public wrappers around the GF coding kernels.

`impl` selects the execution path:
  * 'jnp'    — table-based jnp oracle (fast on CPU, default here)
  * 'pallas' — the Pallas TPU kernel (interpret=True on CPU)
  * 'auto'   — pallas on TPU backends, jnp elsewhere
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .gf_matmul import gf_matmul_pallas
from .gf2_xor import gf2_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gf_matmul(A, P, *, s: int = 8, impl: str = "auto") -> jnp.ndarray:
    """C = A·P over GF(2^s); dispatches jnp / Pallas."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        if s == 1:
            return ref.gf2_matmul_ref(A, P)
        return ref.gf_matmul_ref(A, P, s)
    if impl == "pallas":
        interpret = not _on_tpu()
        if s == 1:
            return gf2_matmul_pallas(A, P, interpret=interpret)
        return gf_matmul_pallas(A, P, s=s, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


def gf2_combine(A, P, *, impl: str = "auto") -> jnp.ndarray:
    """GF(2) byte-stream combine (s=1 fast path, coefficient bits)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return ref.gf2_matmul_ref(A, P)
    if impl == "pallas":
        return gf2_matmul_pallas(A, P, interpret=not _on_tpu())
    raise ValueError(f"unknown impl {impl!r}")
