"""Pure-jnp oracles + CPU production paths for the GF coding kernels.

`gf_matmul_ref` (table-based) is the correctness oracle the Pallas
kernels are tested against (interpret=True on CPU) — an independent
formulation from the kernels' carry-less multiply, so agreement is
meaningful.

`gf_matmul_clmul_ref` / `gf_matmul_packed_ref` re-express the two
Pallas kernel formulations (unpacked clmul, int32 lane-packed ladder)
in pure jnp.  They exist so the kernel *algorithms* can be timed and
oracle-checked on CPU without Pallas interpret-mode overhead — the
packed one is also the fastest CPU path and is registered as
`jnp_packed` with the engine kernel registry.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.gf import get_field, xor_reduce


def gf_matmul_ref(A: jnp.ndarray, P: jnp.ndarray, s: int) -> jnp.ndarray:
    """C = A·P over GF(2^s). A: (n, K) uint8, P: (K, L) uint8."""
    return get_field(s).matmul(A, P)


def gf_matmul_clmul_ref(A: jnp.ndarray, P: jnp.ndarray, s: int
                        ) -> jnp.ndarray:
    """Unpacked carry-less-multiply formulation in pure jnp.

    Bitwise-identical math to the `gf_matmul_pallas` kernel body (one
    symbol per int32 lane), looped over k to keep memory at O(n·L).
    """
    from .gf_matmul import _gf_mul_vec  # late: ref must stay import-light

    A32 = jnp.asarray(A, jnp.uint8).astype(jnp.int32)
    P32 = jnp.asarray(P, jnp.uint8).astype(jnp.int32)
    n, K = A32.shape
    L = P32.shape[1]
    acc = jnp.zeros((n, L), jnp.int32)
    for k in range(K):
        coeff = jnp.broadcast_to(A32[:, k][:, None], acc.shape)
        row = jnp.broadcast_to(P32[k][None, :], acc.shape)
        acc = acc ^ _gf_mul_vec(coeff, row, s)
    return acc.astype(jnp.uint8)


def gf_matmul_packed_ref(A: jnp.ndarray, P: jnp.ndarray, s: int
                         ) -> jnp.ndarray:
    """Lane-packed formulation in pure jnp: 4 symbols per int32 word.

    Same ladder as `gf_matmul_pallas_packed`: precompute P_k·x^i once
    per packet row (shared by all n outputs), then XOR-select by the
    coefficient bits.  ~4x fewer vector ops per symbol than the
    unpacked clmul path — the production CPU encode/decode kernel.
    """
    from .gf_matmul import _xtime_packed, pack_lanes, unpack_lanes

    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    n, K = A.shape
    L = P.shape[1]
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)
    W = pack_lanes(P)                                  # (K, Lw)
    A32 = A.astype(jnp.int32)
    acc = jnp.zeros((n, W.shape[1]), jnp.int32)
    for k in range(K):                                 # static, K small
        w = W[k][None, :]
        coeff = A32[:, k][:, None]
        for i in range(s):
            bit = (coeff >> i) & 1
            acc = acc ^ (w * bit)
            if i + 1 < s:
                w = _xtime_packed(w, s)
    return unpack_lanes(acc, L)


# ---------------------------------------------------------------------------
# seeded variants: coefficient rows regenerated from 4-byte seeds
# ---------------------------------------------------------------------------
#
# Same contract as above but the first operand is (N,) uint32 seeds
# instead of the (N, K) matrix; rows are derived with the counter-based
# Threefry stream in repro.core.seeds.  `expand_rows(seeds) == A` is
# the bit-exactness oracle tying the two families together.

def gf_matmul_seeded_ref(seeds: jnp.ndarray, P: jnp.ndarray, s: int
                         ) -> jnp.ndarray:
    """Seeded table-oracle: expand rows, then the log/exp matmul.

    The correctness reference for the seeded family — it *does*
    materialize A (that is the point: an independent formulation the
    fused kernels must match byte for byte).
    """
    from repro.core.seeds import expand_rows

    A = expand_rows(seeds, int(P.shape[0]), s)
    return get_field(s).matmul(A, P)


def gf_matmul_packed_seeded_ref(seeds: jnp.ndarray, P: jnp.ndarray,
                                s: int) -> jnp.ndarray:
    """Seeded lane-packed ladder: coefficients generated in the k loop.

    The xtime ladder of :func:`gf_matmul_packed_ref`, but column k's
    coefficients come from the Threefry word stream instead of a
    materialized A — only the (N, ceil(K/4)) uint32 word block exists
    inside the jit, and XLA fuses its byte extraction straight into
    the ladder's bit-select.
    """
    from repro.core.seeds import COEFFS_PER_WORD, coeff_words

    from .gf_matmul import _xtime_packed, pack_lanes, unpack_lanes

    seeds = jnp.asarray(seeds)
    P = jnp.asarray(P, jnp.uint8)
    K, L = P.shape
    n = seeds.shape[0]
    if L == 0:
        return jnp.zeros((n, 0), jnp.uint8)
    W = pack_lanes(P)                                  # (K, Lw)
    words = coeff_words(seeds, -(-K // COEFFS_PER_WORD))
    mask = jnp.int32((1 << s) - 1)
    acc = jnp.zeros((n, W.shape[1]), jnp.int32)
    for k in range(K):                                 # static, K small
        w = W[k][None, :]
        byte = (words[:, k // COEFFS_PER_WORD]
                >> jnp.uint32(8 * (k % COEFFS_PER_WORD)))
        coeff = (byte.astype(jnp.int32) & mask)[:, None]
        for i in range(s):
            bit = (coeff >> i) & 1
            acc = acc ^ (w * bit)
            if i + 1 < s:
                w = _xtime_packed(w, s)
    return unpack_lanes(acc, L)


def gf2_matmul_ref(A: jnp.ndarray, P: jnp.ndarray) -> jnp.ndarray:
    """GF(2) fast path: coefficients in {0,1}, symbols = raw bytes.

    C[i] = XOR over {k : A[i,k]=1} of P[k].  Operates on whole bytes —
    for s=1 the linear combination is coefficient-wise XOR regardless of
    how the byte is split into bits.
    """
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    masked = jnp.where((A[:, :, None] & 1) != 0, P[None, :, :], jnp.uint8(0))
    return xor_reduce(masked, axis=1)
