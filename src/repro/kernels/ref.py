"""Pure-jnp oracles for the GF coding kernels.

These are the correctness references the Pallas kernels are tested
against (interpret=True on CPU).  They use the table-based field ops
from repro.core.gf — an independent implementation from the kernels'
carry-less-multiply formulation, so agreement is meaningful.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.gf import get_field, xor_reduce


def gf_matmul_ref(A: jnp.ndarray, P: jnp.ndarray, s: int) -> jnp.ndarray:
    """C = A·P over GF(2^s). A: (n, K) uint8, P: (K, L) uint8."""
    return get_field(s).matmul(A, P)


def gf2_matmul_ref(A: jnp.ndarray, P: jnp.ndarray) -> jnp.ndarray:
    """GF(2) fast path: coefficients in {0,1}, symbols = raw bytes.

    C[i] = XOR over {k : A[i,k]=1} of P[k].  Operates on whole bytes —
    for s=1 the linear combination is coefficient-wise XOR regardless of
    how the byte is split into bits.
    """
    A = jnp.asarray(A, jnp.uint8)
    P = jnp.asarray(P, jnp.uint8)
    masked = jnp.where((A[:, :, None] & 1) != 0, P[None, :, :], jnp.uint8(0))
    return xor_reduce(masked, axis=1)
