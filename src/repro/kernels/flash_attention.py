"""Pallas TPU kernel: causal flash attention (online softmax).

§Perf motivation (EXPERIMENTS.md, Pair B): the prefill roofline is
memory-bound because the q-chunked pure-JAX attention still
materializes (B, H, chunk, T) probabilities in HBM.  This kernel keeps
the running max / normalizer / accumulator in VMEM and never writes
scores out — the standard flash schedule, tiled for the MXU
(block sizes multiples of 128).

Layout: q/k/v arrive as (BH, S, hd) (heads folded into batch); the
grid is (BH, S/block_q); each program loops over k-blocks with an
online-softmax carry.  Validated in interpret mode against the
pure-jnp oracle (models.attention._attend) in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
            seq_len: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    hd = q.shape[-1]

    n_kblocks = seq_len // block_k
    if causal:
        # blocks beyond the diagonal are fully masked; loop bound is
        # data-independent per q-block index
        last = (qi + 1) * block_q
        n_live = (last + block_k - 1) // block_k
    else:
        n_live = n_kblocks

    def body(j, carry):
        acc, m, l = carry
        # NB: the leading batch index must be a Slice, not a python int —
        # jax 0.4.37's interpret-mode discharge rule rejects scalar
        # indexers inside pl.load (AttributeError on `.shape`).
        k = pl.load(k_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))   # (bq,)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention_folded(q, k, v, *, causal: bool = True,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q/k/v: (BH, S, hd) with S divisible by the block sizes."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / np.sqrt(hd)
    grid = (BH, S // block_q)
    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q/k/v: (B, S, H, hd) — GQA callers expand KV first.  Pads S to
    the block size; returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    pad = (-S) % max(block_q, block_k)
    if pad and not causal:
        raise ValueError("non-causal flash requires S % block == 0 "
                         "(zero-padded keys would receive attention)")
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    Sp = S + pad
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    out = flash_attention_folded(fold(q), fold(k), fold(v),
                                 causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
