"""Checkpointing: pytrees <-> npz + JSON manifest.

Keys are slash-joined tree paths, so checkpoints are stable across
process restarts and inspectable with plain numpy.  `restore` places
leaves onto an optional NamedSharding tree (multi-host restore path).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16: widen
            arr = arr.astype(np.float32)   # (lossless; load casts back)
        flat[name] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_pytree(path: str, tree: Any, *, metadata: Optional[dict] = None
                ) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten_with_names(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    manifest = {
        "keys": sorted(flat),
        "metadata": metadata or {},
    }
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of `like` (names must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        name = "/".join(_key_str(k) for k in p)
        arr = npz[name]
        leaves.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(path: str, like: Any, shardings: Optional[Any] = None) -> Any:
    tree = load_pytree(path, like)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


# convenience aliases
save = save_pytree
