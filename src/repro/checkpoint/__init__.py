"""Sharding-aware npz checkpointing for parameter/optimizer pytrees."""
from .ckpt import load_pytree, restore, save, save_pytree

__all__ = ["load_pytree", "restore", "save", "save_pytree"]
