"""Native optimizers (no optax dependency): SGD, momentum, Adam, AdamW."""
from .base import Optimizer, OptState, apply_updates
from .optimizers import adam, adamw, momentum, sgd
from .schedule import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "Optimizer", "OptState", "apply_updates", "adam", "adamw",
    "momentum", "sgd", "constant", "cosine_decay",
    "linear_warmup_cosine",
]
