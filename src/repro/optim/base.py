"""Optimizer interface: pure-functional (init, update) pairs.

An Optimizer is a pair of closures over hyperparameters:
    state   = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params  = apply_updates(params, updates)

States are pytrees matching the parameter tree (so they shard with the
same PartitionSpecs in the launcher), plus a scalar step counter.
`state_dtype` lets big-model configs keep moments in bf16
(DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    slots: Any                 # optimizer-specific pytree (or ())


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )


def tree_zeros_like(params: Any, dtype=None) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda l: l * scale.astype(l.dtype), tree)
