"""SGD / momentum / Adam / AdamW, schedule-aware."""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from .base import Optimizer, OptState, tree_zeros_like

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return OptState(step=jnp.int32(0), slots=())

    def update(grads, state, params=None):
        eta = _lr_at(lr, state.step)
        upd = jax.tree_util.tree_map(
            lambda g: (-eta * g.astype(jnp.float32)), grads)
        return upd, OptState(step=state.step + 1, slots=())

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9,
             state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return OptState(step=jnp.int32(0),
                        slots=tree_zeros_like(params, state_dtype))

    def update(grads, state, params=None):
        eta = _lr_at(lr, state.step)
        new_m = jax.tree_util.tree_map(
            lambda m, g: (beta * m.astype(jnp.float32)
                          + g.astype(jnp.float32)).astype(state_dtype),
            state.slots, grads)
        upd = jax.tree_util.tree_map(
            lambda m: -eta * m.astype(jnp.float32), new_m)
        return upd, OptState(step=state.step + 1, slots=new_m)

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, state_dtype=jnp.float32) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0,
                 state_dtype=state_dtype)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01,
          state_dtype=jnp.float32) -> Optimizer:
    """AdamW with bias correction; moments stored in `state_dtype`."""

    def init(params):
        return OptState(
            step=jnp.int32(0),
            slots={"m": tree_zeros_like(params, state_dtype),
                   "v": tree_zeros_like(params, state_dtype)},
        )

    def update(grads, state, params):
        step = state.step + 1
        eta = _lr_at(lr, state.step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd_m(m, g):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(state_dtype)

        def upd_v(v, g):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(state_dtype)

        new_m = jax.tree_util.tree_map(upd_m, state.slots["m"], grads)
        new_v = jax.tree_util.tree_map(upd_v, state.slots["v"], grads)

        def step_fn(m, v, p):
            mhat = m.astype(jnp.float32) / c1
            vhat = v.astype(jnp.float32) / c2
            u = -eta * (mhat / (jnp.sqrt(vhat) + eps)
                        + weight_decay * p.astype(jnp.float32))
            return u

        upd = jax.tree_util.tree_map(step_fn, new_m, new_v, params)
        return upd, OptState(step=step, slots={"m": new_m, "v": new_v})

    return Optimizer(init, update)
