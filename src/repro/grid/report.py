"""The GRID_*.json artifact and its markdown summary table.

``grid_document`` assembles the structured artifact — schema-tagged so
``scripts/check_bench.py`` can validate it exactly like the
``BENCH_*.json`` family — and ``markdown_report`` renders the human
view (also reachable as ``python scripts/make_report.py --grid``).
"""
from __future__ import annotations

from typing import Mapping, Optional

GRID_SCHEMA = "fednc-grid-v1"

#: the coordinate keys every scenario entry records
AXIS_NAMES = ("strategy", "straggler", "delay_spread", "p_dropout",
              "population", "kernel", "adversary")
#: Prop.-1 measurement fields every simulator scenario must carry
#: (null allowed only under dropout, where FedAvg never completes)
DRAW_RATIO_FIELDS = ("fednc_draws_mean", "fedavg_draws_mean",
                     "draw_ratio")


def grid_document(config: dict, scenarios: Mapping[str, dict],
                  *, full: bool = False,
                  delay_sweep: Optional[dict] = None,
                  compute_coupling: Optional[dict] = None) -> dict:
    """Assemble the schema-tagged artifact check_bench validates."""
    doc = {
        "schema": GRID_SCHEMA,
        "config": {**config, "full": bool(full)},
        "scenarios": dict(scenarios),
    }
    if delay_sweep is not None:
        doc["delay_sweep"] = delay_sweep
    if compute_coupling is not None:
        doc["compute_coupling"] = compute_coupling
    return doc


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


def markdown_report(doc: dict) -> str:
    """Render one GRID_*.json document as markdown tables."""
    cfg = doc.get("config", {})
    lines = [
        "# Scenario grid report",
        "",
        f"schema `{doc.get('schema', '?')}` · "
        f"K={cfg.get('clients_per_round', '?')} · "
        f"rounds={cfg.get('rounds', '?')} · "
        f"base_seed={cfg.get('base_seed', '?')} · "
        f"{len(doc.get('scenarios', {}))} scenarios",
        "",
        "## Scenarios",
        "",
        "| scenario | strategy | straggler | delay | dropout | pop "
        "| kernel | adversary | draw ratio | FedAvg/K·H(K) "
        "| time speedup | decode rate | leak rate | wall s |",
        "|---|---|---|---:|---:|---:|---|---|---:|---:|---:|---:"
        "|---:|---:|",
    ]
    for name, e in doc.get("scenarios", {}).items():
        ax = e.get("axes", {})
        decode = e.get("decode_rate", e.get("fednc_decode_rate"))
        lines.append(
            "| " + " | ".join([
                f"`{name}`", ax.get("strategy", "?"),
                ax.get("straggler", "?"),
                _fmt(ax.get("delay_spread")), _fmt(ax.get("p_dropout")),
                _fmt(ax.get("population")), ax.get("kernel", "?"),
                ax.get("adversary", "none"),
                _fmt(e.get("draw_ratio")),
                _fmt(e.get("fedavg_inflation")),
                _fmt(e.get("time_speedup")),
                _fmt(decode), _fmt(e.get("full_leak_rate")),
                _fmt(e.get("wall_s")),
            ]) + " |")
    sweep = doc.get("delay_sweep")
    if sweep:
        lines += [
            "",
            "## Delay-reordered sweep (FedAvg inflation over K·H(K))",
            "",
            f"K={sweep.get('clients_per_round', '?')}, "
            f"K·H(K)={_fmt(sweep.get('kh_k'))}; per-client reorder "
            "offsets break the blind-box i.i.d. assumption, so the "
            "FedAvg collector pays *more* than the coupon bound while "
            "FedNC's rank law is order-invariant:",
            "",
            "| reorder spread | FedAvg draws | inflation vs K·H(K) "
            "| FedNC draws | draw ratio |",
            "|---:|---:|---:|---:|---:|",
        ]
        for i, d in enumerate(sweep.get("spreads", [])):
            lines.append(
                f"| {_fmt(d)} | {_fmt(sweep['fedavg_draws_mean'][i])} "
                f"| {_fmt(sweep['inflation'][i])}x "
                f"| {_fmt(sweep['fednc_draws_mean'][i])} "
                f"| {_fmt(sweep['draw_ratio'][i])} |")
    cc = doc.get("compute_coupling")
    if cc:
        lines += [
            "",
            "## Compute-coupled arrivals",
            "",
            f"per-round decode clock with local-training compute folded "
            f"into the schedule: coupled "
            f"{_fmt(cc.get('sim_time_mean'))}s vs network-only "
            f"{_fmt(cc.get('sim_time_network_mean'))}s "
            f"(strict domination: "
            f"{_fmt(cc.get('dominates', cc.get('compute_dominates')))}"
            ").",
        ]
    return "\n".join(lines) + "\n"
