"""repro.grid — the declarative scenario-grid runner.

FedNC's headline claims (Prop. 1 efficiency, straggler/dropout
robustness, the §III hierarchy) are temporal and *regime-dependent*:
a single straggler profile or a single population size proves very
little.  This package turns "measure everything" into a declarative
matrix:

spec.py    — :class:`GridAxes` (the cartesian axes: straggler
             distribution, delay reordering, dropout, population size,
             strategy, GF kernel backend) expanded into frozen,
             picklable :class:`ScenarioSpec` records with stable
             per-scenario seeds (``crc32(name) ^ base_seed`` — adding
             or reordering axes never reseeds existing scenarios).
execute.py — one executor per strategy family: the network-simulator
             strategies run :class:`repro.sim.NetworkSimulator`, the
             hierarchical ones run the engine's fused
             ``multi_edge_round``, and the async-FL ones run
             ``federation.async_rounds.run_async_experiment`` with a
             compute-coupled arrival schedule.  ``run_grid`` fans
             scenarios over worker *processes* (spawn context — each
             worker owns its own jax runtime).
report.py  — the ``GRID_*.json`` artifact (schema-checked by
             ``scripts/check_bench.py`` exactly like ``BENCH_*.json``)
             and its markdown summary table (also reachable via
             ``python scripts/make_report.py --grid``).
__main__   — ``python -m repro.grid`` CLI; ``--smoke`` is the tiny
             2x2 grid CI runs end to end on every push.

See docs/grid.md for the axes, the schema, and the CI wiring.
"""
from .execute import run_grid, run_scenario
from .report import GRID_SCHEMA, grid_document, markdown_report
from .spec import GridAxes, ScenarioSpec, scenario_seed

__all__ = [
    "GridAxes", "ScenarioSpec", "scenario_seed",
    "run_grid", "run_scenario",
    "GRID_SCHEMA", "grid_document", "markdown_report",
]
