"""Scenario axes and their expansion into frozen ScenarioSpec records.

The grid is a cartesian product over seven axes; a scenario is one cell.
Two properties the rest of the machinery leans on:

* **Normalization before product** — axes that cannot affect a
  strategy are collapsed to a canonical value before the product is
  deduplicated (the GF kernel never touches the network simulator;
  delay reordering never touches a hierarchical coding round), so the
  grid enumerates *distinct measurements*, not redundant reruns.
* **Stable seeds** — each scenario's seed is
  ``crc32(name) ^ base_seed``: a pure function of the scenario's own
  coordinates.  Growing the grid, reordering axes, or filtering
  scenarios never changes the seed (and therefore the trace) of any
  existing cell.
"""
from __future__ import annotations

import itertools
import zlib
from dataclasses import dataclass, replace

#: strategy families -> which executor runs them (see execute.py)
SIM_STRATEGIES = ("fednc_stream", "fednc_stages", "fedavg")
HIER_PREFIX = "hier:"          # "hier:4" = §III hierarchy at E=4 edges
ASYNC_STRATEGIES = ("async", "async_compute")
ENGINE_STRATEGY = "engine"     # flat fused engine rounds (kernel axis)


def scenario_seed(name: str, base_seed: int = 0) -> int:
    """Deterministic, order-independent per-scenario seed."""
    return (zlib.crc32(name.encode("utf-8")) ^ (base_seed & 0xFFFFFFFF)
            ) & 0x7FFFFFFF


@dataclass(frozen=True)
class ScenarioSpec:
    """One grid cell: every knob a scenario run needs, picklable."""

    name: str
    strategy: str              # SIM_STRATEGIES | "hier:E" | ASYNC_*
    straggler: str             # repro.sim.STRAGGLER_PROFILES key
    delay_spread: float        # mean per-client reorder offset; 0 = off
    p_dropout: float           # mid-round silent-failure probability
    population: int            # clients in the population
    kernel: str                # engine registry name ("-" = unused)
    clients_per_round: int
    rounds: int
    s: int = 8
    seed: int = 0
    adversary: str = "none"    # repro.adversary axis (kind:param)

    @property
    def num_edges(self) -> int:
        """E for hierarchical strategies, 0 otherwise."""
        if self.strategy.startswith(HIER_PREFIX):
            return int(self.strategy[len(HIER_PREFIX):])
        return 0

    @property
    def compute_coupled(self) -> bool:
        return self.strategy == "async_compute"

    def axes(self) -> dict:
        """The scenario's coordinates, as recorded in GRID_*.json."""
        return {
            "strategy": self.strategy,
            "straggler": self.straggler,
            "delay_spread": self.delay_spread,
            "p_dropout": self.p_dropout,
            "population": self.population,
            "kernel": self.kernel,
            "adversary": self.adversary,
        }


@dataclass(frozen=True)
class GridAxes:
    """The declarative grid: list the values per axis, call expand().

    >>> g = GridAxes(strategy=("fednc_stream", "fedavg"),
    ...              straggler=("exponential", "pareto"))
    >>> [s.name for s in g.expand()]  # doctest: +NORMALIZE_WHITESPACE
    ['fednc_stream-exponential-d0-p0-n10000-k-',
     'fednc_stream-pareto-d0-p0-n10000-k-',
     'fedavg-exponential-d0-p0-n10000-k-',
     'fedavg-pareto-d0-p0-n10000-k-']
    """

    strategy: tuple = ("fednc_stream", "fedavg")
    straggler: tuple = ("exponential", "pareto")
    delay_spread: tuple = (0.0,)
    p_dropout: tuple = (0.0,)
    population: tuple = (10_000,)
    kernel: tuple = ("auto",)
    adversary: tuple = ("none",)
    # shared (non-axis) knobs
    clients_per_round: int = 32
    rounds: int = 20
    s: int = 8
    base_seed: int = 0

    def expand(self) -> list:
        """Normalized, deduplicated cartesian expansion."""
        specs: list[ScenarioSpec] = []
        seen: set[str] = set()
        for combo in itertools.product(
                self.strategy, self.straggler, self.delay_spread,
                self.p_dropout, self.population, self.kernel,
                self.adversary):
            spec = self._make(*combo)
            if spec.name in seen:
                continue
            seen.add(spec.name)
            specs.append(spec)
        return specs

    def _make(self, strategy: str, straggler: str, delay: float,
              dropout: float, population: int, kernel: str,
              adversary: str = "none") -> ScenarioSpec:
        from repro.adversary import AdversarySpec
        adv = AdversarySpec.parse(adversary)    # validate early
        if strategy in SIM_STRATEGIES:
            kernel = "-"          # simulator never runs a GF kernel
            adv = AdversarySpec()  # arrival stream carries no payload
        elif strategy.startswith(HIER_PREFIX):
            delay = 0.0           # no arrival stream in a coding round
            straggler = "-"
            population = self.clients_per_round
            if adv.kind != "eavesdrop":
                # hierarchical cells model the edge-link tap; active /
                # colluding adversaries are the flat engine's axis
                adv = AdversarySpec()
        elif strategy in ASYNC_STRATEGIES:
            kernel = "-"          # engine kernel fixed by FedNCConfig
            dropout = 0.0         # async driver has no dropout knob yet
            delay = 0.0           # schedule_fn owns the arrival model
            adv = AdversarySpec()  # no per-round coded batch to attack
        elif strategy == ENGINE_STRATEGY:
            delay = 0.0           # no arrival stream in a coding round
            straggler = "-"
            population = self.clients_per_round
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        name = (f"{strategy.replace(':', '')}-{straggler}"
                f"-d{delay:g}-p{dropout:g}-n{population}-k{kernel}")
        # suffix only under an active adversary, so adding the axis
        # never renames (= never reseeds) any pre-existing cell
        if not adv.none:
            name += f"-a{adv.tag}"
        return ScenarioSpec(
            name=name, strategy=strategy, straggler=straggler,
            delay_spread=float(delay), p_dropout=float(dropout),
            population=int(population), kernel=kernel,
            clients_per_round=self.clients_per_round,
            rounds=self.rounds, s=self.s,
            seed=scenario_seed(name, self.base_seed),
            adversary=str(adv))

    def config(self) -> dict:
        """The grid-level record written into GRID_*.json."""
        return {
            "axes": {
                "strategy": list(self.strategy),
                "straggler": list(self.straggler),
                "delay_spread": list(self.delay_spread),
                "p_dropout": list(self.p_dropout),
                "population": list(self.population),
                "kernel": list(self.kernel),
                "adversary": list(self.adversary),
            },
            "clients_per_round": self.clients_per_round,
            "rounds": self.rounds,
            "s": self.s,
            "base_seed": self.base_seed,
        }


def with_rounds(spec: ScenarioSpec, rounds: int) -> ScenarioSpec:
    """A copy of `spec` at a different round count (same seed/name)."""
    return replace(spec, rounds=int(rounds))
