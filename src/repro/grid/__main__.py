"""``python -m repro.grid`` — run a scenario grid from the command line.

    PYTHONPATH=src python -m repro.grid --smoke          # CI's 2x2 grid
    PYTHONPATH=src python -m repro.grid \
        --strategies fednc_stream fedavg hier:4 \
        --stragglers lognormal pareto --populations 1000 100000 \
        --rounds 30 --jobs 2 --out mygrid

Writes ``GRID_<out>.json`` (schema ``fednc-grid-v1``, validated by
``scripts/check_bench.py``) and ``GRID_<out>.md`` (the markdown
summary, same renderer as ``scripts/make_report.py --grid``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro import obs

from .execute import run_grid
from .report import grid_document, markdown_report
from .spec import GridAxes


def smoke_axes() -> GridAxes:
    """The CI smoke grid: small enough to finish well under a minute
    on two CPU cores yet covering the StreamDecoder, the blind-box
    collector, and — via the ``engine`` cells — both the materialized
    and the seeded GF-kernel families end-to-end.  The adversary axis
    rides the engine cells (it collapses to ``none`` everywhere else),
    adding an eavesdropper cell validated against the closed-form leak
    probability and a byzantine cell exercising detection + recovery
    per kernel family."""
    return GridAxes(
        strategy=("fednc_stream", "fedavg", "engine"),
        straggler=("exponential", "pareto"),
        population=(2_000,),
        kernel=("jnp_packed", "jnp_packed_seeded"),
        adversary=("none", "eavesdrop:0.6", "byzantine:0.05"),
        clients_per_round=32,
        rounds=10,
        base_seed=7,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.grid",
        description="declarative FedNC scenario-grid runner")
    ap.add_argument("--smoke", action="store_true",
                    help="run the tiny 2x2 CI grid (GRID_smoke.json)")
    ap.add_argument("--strategies", nargs="+",
                    default=["fednc_stream", "fedavg"])
    ap.add_argument("--stragglers", nargs="+",
                    default=["exponential", "pareto"])
    ap.add_argument("--delay-spreads", nargs="+", type=float,
                    default=[0.0])
    ap.add_argument("--dropouts", nargs="+", type=float, default=[0.0])
    ap.add_argument("--populations", nargs="+", type=int,
                    default=[10_000])
    ap.add_argument("--kernels", nargs="+", default=["auto"])
    ap.add_argument("--adversaries", nargs="+", default=["none"],
                    help="adversary axis values: none, eavesdrop:p, "
                         "collude:c, byzantine:b")
    ap.add_argument("--clients-per-round", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs", type=int, default=2,
                    help="worker processes (1 = in-process)")
    ap.add_argument("--out", default=None,
                    help="artifact suffix: GRID_<out>.json/.md "
                         "(default: 'smoke' with --smoke, else 'cli')")
    ap.add_argument("--outdir", default=".",
                    help="directory for the GRID_* artifacts")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also write the merged Chrome trace "
                         "(default PATH: TRACE_grid_<out>.json)")
    args = ap.parse_args(argv)

    if args.smoke:
        axes = smoke_axes()
        out = args.out or "smoke"
    else:
        axes = GridAxes(
            strategy=tuple(args.strategies),
            straggler=tuple(args.stragglers),
            delay_spread=tuple(args.delay_spreads),
            p_dropout=tuple(args.dropouts),
            population=tuple(args.populations),
            kernel=tuple(args.kernels),
            adversary=tuple(args.adversaries),
            clients_per_round=args.clients_per_round,
            rounds=args.rounds, base_seed=args.seed)
        out = args.out or "cli"

    specs = axes.expand()
    print(f"grid: {len(specs)} scenarios, jobs={args.jobs}", flush=True)
    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    trace_path = None
    if args.trace is not None:
        trace_path = (pathlib.Path(args.trace) if args.trace
                      else outdir / f"TRACE_grid_{out}.json")
    with obs.timed("grid.run", cat="grid") as sw:
        results = run_grid(
            specs, jobs=args.jobs, trace_path=trace_path,
            progress=lambda s: print(f"  {s}", flush=True))

    doc = grid_document(axes.config(), results)
    doc["wall_s"] = sw.dur_s
    json_path = outdir / f"GRID_{out}.json"
    md_path = outdir / f"GRID_{out}.md"
    json_path.write_text(json.dumps(doc, indent=2))
    md_path.write_text(markdown_report(doc))
    print(f"wrote {json_path} and {md_path} ({sw.dur_s:.1f}s total)")
    if trace_path is not None:
        print(f"wrote {trace_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
