"""Scenario execution: one function per strategy family + the fan-out.

``run_scenario`` is a pure function of its :class:`ScenarioSpec`
(every random draw flows from ``spec.seed``), so scenarios can run in
any order, on any worker, and reproduce bit-identically.  The three
execution backends:

* **simulator** (``fednc_stream`` / ``fednc_stages`` / ``fedavg``) —
  a :class:`repro.sim.NetworkSimulator` run; both collectors ride the
  same arrival stream, so every simulator scenario reports the
  FedNC/FedAvg draw-ratio fields (the Prop. 1 measurement) plus the
  FedAvg inflation over K·H(K) — the quantity the delay-reordering
  axis exists to expose.
* **hierarchy** (``hier:E``) — E-edge fused coding rounds through
  :meth:`repro.engine.CodingEngine.multi_edge_round`, honoring the
  GF-kernel axis; the dropout axis becomes WAN erasure.
* **engine** (``engine``) — flat fused coding rounds through
  :meth:`repro.engine.CodingEngine.round`, honoring the GF-kernel
  axis; this is where the *seeded* kernel family gets grid coverage,
  with per-packet wire-byte accounting (4-byte seed headers vs
  K-symbol materialized rows).
* **async FL** (``async`` / ``async_compute``) — a miniature
  end-to-end training run through ``run_async_experiment``; the
  ``async_compute`` variant couples per-client local-training compute
  time into the arrival clock and reports whether the coupled clock
  dominates the network-only one (it must — offsets are positive).

``run_grid`` fans scenarios over a spawn-context process pool — each
worker owns a fresh jax runtime — and degrades to in-process execution
at ``jobs=1``.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from repro import obs

from .spec import (ASYNC_STRATEGIES, ENGINE_STRATEGY, HIER_PREFIX,
                   SIM_STRATEGIES, ScenarioSpec)

# miniature FL workload for the async scenarios: big enough to train,
# small enough that a grid of them stays interactive
ASYNC_N_IMAGES = 160
ASYNC_N_CLIENTS = 8
ASYNC_IMAGE_SIZE = 16
HIER_L = 2048           # payload symbols per client in hier scenarios
HIER_SPARES = 2
# per-tuple interception probability of a collude:c cell (the axis
# parameter is the colluder count; the tap rate stays fixed so cells
# differ in exactly one variable)
COLLUDE_INTERCEPT_P = 0.5
# recovery episodes measured per byzantine cell: each is a full
# retry-until-verified loop, so the cost is bounded here rather than
# growing with the corruption rate
MAX_RECOVERY_EPISODES = 3

# envelope spans contain the per-stage spans, so they are excluded
# from a cell's per_stage breakdown (they would double-count it)
_ENVELOPE_SPANS = ("grid.scenario", "grid.engine_rounds",
                   "grid.hier_rounds", "engine.round",
                   "engine.multi_edge_round", "fl.round", "async.round",
                   "serve.trace")


def _sim_metrics(spec: ScenarioSpec) -> dict:
    from repro.core import coupon
    from repro.sim import (NetworkSimulator, PopulationConfig, SimConfig,
                           STRAGGLER_PROFILES)
    from repro.sim.distributions import DistSpec

    decoder = {"fednc_stream": "stream", "fednc_stages": "stages",
               "fedavg": "stages"}[spec.strategy]
    delay = (DistSpec("exponential", spec.delay_spread, 0.0)
             if spec.delay_spread > 0 else None)
    cfg = SimConfig(
        population=PopulationConfig(n_clients=spec.population,
                                    p_dropout=spec.p_dropout),
        clients_per_round=spec.clients_per_round, s=spec.s,
        gap=STRAGGLER_PROFILES[spec.straggler], delay=delay,
        decoder=decoder,
        timeout=1e4 if spec.p_dropout > 0 else math.inf,
        seed=spec.seed)
    trace = NetworkSimulator(cfg).run(spec.rounds)
    s = trace.summary()

    K = spec.clients_per_round
    kh_k = coupon.expected_draws_fedavg(K)
    predicted = kh_k / coupon.expected_draws_fednc(K, spec.s)
    m = {
        "fednc_decode_rate": s["fednc_decode_rate"],
        "fedavg_complete_rate": s["fedavg_complete_rate"],
        "n_dropped_mean": s["n_dropped_mean"],
        "kh_k": kh_k,
        "predicted_draw_ratio": predicted,
        # null when FedAvg never completed (dropout blocks its last
        # coupon) — the checker accepts null only for p_dropout > 0
        "fednc_draws_mean": s.get("fednc_draws_mean"),
        "fedavg_draws_mean": s.get("fedavg_draws_mean"),
        "draw_ratio": s.get("draw_ratio"),
    }
    if "draw_ratio" in s:
        m["fedavg_inflation"] = s["fedavg_draws_mean"] / kh_k
        m["time_to_rank_k_mean"] = s["time_to_rank_k_mean"]
        m["time_to_all_k_mean"] = s["time_to_all_k_mean"]
        m["time_speedup"] = s["time_speedup"]
    return m


def _hier_metrics(spec: ScenarioSpec) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.adversary import AdversarySpec, EavesdropperView, tap_edges
    from repro.core.channel import ErasureChannel
    from repro.engine import CodingEngine, EngineConfig

    E = spec.num_edges
    K = spec.clients_per_round
    if E < 1 or K < E:
        raise ValueError(f"hier needs 1 <= E <= K, got E={E} K={K}")
    kernel = spec.kernel if spec.kernel != "-" else "auto"
    engine = CodingEngine(EngineConfig(s=spec.s, kernel=kernel,
                                       chunk_l=HIER_L))
    bounds = np.linspace(0, K, E + 1).astype(int)
    edges = [tuple(range(bounds[e], bounds[e + 1])) for e in range(E)]
    key = jax.random.PRNGKey(spec.seed)
    P = jax.random.randint(jax.random.fold_in(key, 10**6),
                           (K, HIER_L), 0, 1 << spec.s,
                           dtype=jnp.uint8)
    wan = (ErasureChannel(p_erase=spec.p_dropout, seed=spec.seed)
           if spec.p_dropout > 0 else None)
    adv = AdversarySpec.parse(spec.adversary)
    n_out = [len(ids) + HIER_SPARES for ids in edges]
    adv_rng = np.random.default_rng(spec.seed ^ 0x5EC)
    ev_reports: list[dict] = []
    ok_rounds = 0
    with obs.timed("grid.hier_rounds", cat="grid",
                   rounds=spec.rounds) as sw:
        out = None
        for r in range(spec.rounds):
            rk = jax.random.fold_in(key, r)
            out = engine.multi_edge_round(
                P, rk, edges, spare_per_edge=HIER_SPARES,
                wan_channel=wan)
            if out.ok:
                assert (out.packets == P).all()
                ok_rounds += 1
            if adv.kind == "eavesdrop":
                # the attacker taps ceil(p·E) edge->server links; the
                # stacked matrix is reconstructed from the round key
                # (same draw the fused round consumed)
                n_tap = max(1, math.ceil(adv.param * E))
                tapped = adv_rng.choice(E, size=min(n_tap, E),
                                        replace=False)
                A = engine.multi_edge_coding_matrix(rk, edges, K, n_out)
                view = EavesdropperView(K=K, s=spec.s)
                view.observe(tap_edges(A, edges, tapped,
                                       spare_per_edge=HIER_SPARES))
                rep = view.report()
                rep["tapped_edges"] = int(len(tapped))
                ev_reports.append(rep)
        if out is not None:      # fence before the clock stops
            sw.fence(out.packets)
    m = {
        "num_edges": E,
        "kernel_resolved": engine.kernel_name,
        "payload_symbols": K * HIER_L,
        "decode_rate": ok_rounds / max(spec.rounds, 1),
        "wall_s_per_round": sw.dur_s / max(spec.rounds, 1),
    }
    if ev_reports:
        partial = [rp for rp in ev_reports
                   if rp["tapped_edges"] < E]
        m.update({
            "tapped_edges_mean": float(np.mean(
                [rp["tapped_edges"] for rp in ev_reports])),
            "eavesdrop_rank_mean": float(np.mean(
                [rp["rank"] for rp in ev_reports])),
            "full_leak_rate": float(np.mean(
                [rp["full_leak"] for rp in ev_reports])),
            # the e < K claim, structurally: any untapped edge leaves
            # its member columns entirely outside the captured span
            "rank_wall_holds": bool(all(rp["rank"] < K
                                        for rp in partial)),
        })
    return m


def _engine_metrics(spec: ScenarioSpec) -> dict:
    """Flat fused engine rounds honoring the kernel axis.

    This is the grid cell that exercises the *seeded* kernel family
    end-to-end: a seeded kernel name on the axis makes `round()` draw
    4-byte row seeds and regenerate coefficients in-kernel, and the
    entry reports the wire economics (header bytes per packet drop
    from K·s/8 to 4) alongside decode correctness against the known
    packet matrix."""
    import jax
    import jax.numpy as jnp

    from repro.adversary import AdversarySpec
    from repro.core.channel import ErasureChannel
    from repro.core.packets import packet_wire_bytes
    from repro.engine import CodingEngine, EngineConfig

    K = spec.clients_per_round
    kernel = spec.kernel if spec.kernel != "-" else "auto"
    adv = AdversarySpec.parse(spec.adversary)
    # dropout needs erasure headroom; byzantine detection needs
    # redundant rank for the cross-check (decode_verified docstring)
    extra = (HIER_SPARES if spec.p_dropout > 0
             or adv.kind == "byzantine" else 0)
    engine = CodingEngine(EngineConfig(s=spec.s, kernel=kernel,
                                       chunk_l=HIER_L,
                                       extra_tuples=extra))
    key = jax.random.PRNGKey(spec.seed)
    P = jax.random.randint(jax.random.fold_in(key, 10**6),
                           (K, HIER_L), 0, 1 << spec.s,
                           dtype=jnp.uint8)
    channel = (ErasureChannel(p_erase=spec.p_dropout, seed=spec.seed)
               if spec.p_dropout > 0 else None)
    n_tuples = K + extra
    adv_metrics: dict = {}
    ok_rounds = 0
    with obs.timed("grid.engine_rounds", cat="grid",
                   rounds=spec.rounds) as sw:
        if adv.kind == "byzantine":
            out, ok_rounds, adv_metrics = _byzantine_rounds(
                engine, P, key, spec, adv)
        else:
            out = None
            views = []
            for r in range(spec.rounds):
                rk = jax.random.fold_in(key, r)
                out = engine.round(P, rk, channel=channel)
                if out.ok:
                    assert (out.packets == P).all()
                    ok_rounds += 1
                if adv.kind in ("eavesdrop", "collude"):
                    views.append(_observe_round(engine, rk, n_tuples,
                                                K, spec, adv))
            if views:
                adv_metrics = _eavesdrop_summary(views, n_tuples, K,
                                                 spec, adv)
        if out is not None:      # fence before the clock stops
            sw.fence(out.packets)
    wire = packet_wire_bytes(K, HIER_L, spec.s, seeded=engine.seeded)
    wire_mat = packet_wire_bytes(K, HIER_L, spec.s, seeded=False)
    return {
        "kernel_resolved": engine.kernel_name,
        "seeded": engine.seeded,
        "payload_symbols": K * HIER_L,
        "decode_rate": ok_rounds / max(spec.rounds, 1),
        "wall_s_per_round": sw.dur_s / max(spec.rounds, 1),
        "wire_bytes_per_packet": wire,
        "wire_bytes_per_round": wire * n_tuples,
        "wire_overhead_ratio": wire / wire_mat,
        **adv_metrics,
    }


def _observe_round(engine, round_key, n_tuples: int, K: int,
                   spec: ScenarioSpec, adv) -> dict:
    """One round through a fresh eavesdropper: reconstruct the rows
    (or 4-byte seed headers — the expansion is public, so they hide
    nothing) the engine transmitted under `round_key`, give the view
    its per-tuple interception coin flips, and return its report."""
    from repro.adversary import EavesdropperView

    p = adv.param if adv.kind == "eavesdrop" else COLLUDE_INTERCEPT_P
    colluders = range(adv.count) if adv.kind == "collude" else ()
    if engine.seeded:
        rows = np.asarray(engine.coding_seeds(round_key, n_tuples))
    else:
        rows = np.asarray(engine.coding_matrix(round_key, n_tuples, K))
    view = EavesdropperView(K=K, s=spec.s, p_intercept=p,
                            seed=int(round_key[0] ^ round_key[1]),
                            colluders=colluders)
    view.intercept(rows)
    return view.report()


def _eavesdrop_summary(views: list, n_tuples: int, K: int,
                       spec: ScenarioSpec, adv) -> dict:
    """Aggregate per-round eavesdropper reports + the closed form they
    are validated against (collusion reduces the attacker's problem to
    rank K - c over the quotient space, so the same formula applies
    with K - c unknowns)."""
    from repro.core.security import eavesdropper_leak_probability

    p = adv.param if adv.kind == "eavesdrop" else COLLUDE_INTERCEPT_P
    c = adv.count if adv.kind == "collude" else 0
    m = {
        "intercepted_mean": float(np.mean(
            [v["intercepted"] for v in views])),
        "eavesdrop_rank_mean": float(np.mean(
            [v["rank"] for v in views])),
        "full_leak_rate": float(np.mean(
            [v["full_leak"] for v in views])),
        "residual_entropy_bits_mean": float(np.mean(
            [v["residual_entropy_bits"] for v in views])),
        "leak_probability_closed_form": eavesdropper_leak_probability(
            n_tuples, K - c, p, spec.s),
    }
    if c:
        m["colluders"] = c
        m["sources_recovered_mean"] = float(np.mean(
            [v["sources_recovered"] for v in views]))
    return m


def _byzantine_rounds(engine, P, key, spec: ScenarioSpec, adv):
    """The byzantine engine loop: every round runs with the redundant-
    rank cross-check on, a round is *accepted* only when it decodes and
    is not flagged, and each rejected round is retried with fresh coded
    tuples — ``rounds_to_recovery`` episodes laid end to end.  Returns
    ``(last_out, accepted_and_correct, metrics)``; decode_rate for a
    byzantine cell therefore reads "verified-clean AND actually
    correct rounds / rounds"."""
    import jax

    from repro.adversary import ByzantineChannel, rounds_to_recovery

    channel = ByzantineChannel(adv.param, seed=spec.seed ^ 0xB12,
                               mode="both")
    recov, flagged, rank_failures = [], 0, 0
    detected = undetected_bad = corrupted_rounds = ok_correct = 0
    out = None
    for r in range(spec.rounds):
        before = channel.corrupted
        rk = jax.random.fold_in(key, r)
        out = engine.round(P, rk, channel=channel, verify=True)
        hit = channel.corrupted > before
        corrupted_rounds += hit
        accepted = out.ok and out.verified is not False
        flagged += int(out.ok and out.verified is False)
        rank_failures += int(not out.ok)
        if accepted:
            correct = bool((out.packets == P).all())
            ok_correct += int(correct)
            undetected_bad += int(hit and not correct)
        elif hit:
            detected += 1
        if not accepted and len(recov) < MAX_RECOVERY_EPISODES:
            # the server's recovery policy: re-request until verified
            # (measured for the first few rejections only — each
            # episode is a full retry loop, too costly per rejection)
            recov.append(rounds_to_recovery(
                engine, P, jax.random.fold_in(rk, 0x7EC0), channel))
    m = {
        "corrupted_round_rate": corrupted_rounds / max(spec.rounds, 1),
        "detection_rate": (detected / corrupted_rounds
                           if corrupted_rounds else 1.0),
        "flagged_rounds": flagged,
        "rank_failures": rank_failures,
        "undetected_bad_decodes": undetected_bad,
        "rounds_to_recovery_mean": (float(np.mean(
            [e["rounds"] for e in recov])) if recov else 1.0),
        "recovery_episodes": len(recov),
    }
    return out, ok_correct, m


def _async_metrics(spec: ScenarioSpec) -> dict:
    import jax

    from repro.core.fednc import FedNCConfig
    from repro.data import iid_partition, make_image_dataset
    from repro.federation import (AsyncFedNCStrategy, FLExperiment,
                                  LocalTrainer, blind_box_schedule,
                                  run_async_experiment)
    from repro.models.cnn import (cnn_accuracy, cnn_loss, init_cnn,
                                  merge_bn_stats)
    from repro.optim import adam
    from repro.sim import ComputeModel
    from repro.sim.distributions import STRAGGLER_PROFILES

    k = min(spec.clients_per_round, ASYNC_N_CLIENTS)
    ds = make_image_dataset(ASYNC_N_IMAGES, seed=spec.seed,
                            size=ASYNC_IMAGE_SIZE)
    test = make_image_dataset(64, seed=spec.seed + 1,
                              size=ASYNC_IMAGE_SIZE)
    parts = iid_partition(ds.labels, ASYNC_N_CLIENTS, seed=spec.seed)
    strat = AsyncFedNCStrategy(
        config=FedNCConfig(s=spec.s), budget=k + 8,
        schedule_fn=blind_box_schedule(
            STRAGGLER_PROFILES[spec.straggler]))
    exp = FLExperiment(
        trainer=LocalTrainer(
            loss_fn=lambda p, b: cnn_loss(p, b, train=True),
            optimizer=adam(1e-3), local_epochs=1,
            state_merge=merge_bn_stats),
        strategy=strat, partitions=parts, dataset=ds, test_set=test,
        eval_fn=lambda p, x, y: cnn_accuracy(p, x, y),
        clients_per_round=k, batch_size=32, seed=spec.seed)
    params = init_cnn(jax.random.PRNGKey(spec.seed),
                      image_size=ASYNC_IMAGE_SIZE)
    compute = (ComputeModel() if spec.compute_coupled else None)
    logs = run_async_experiment(exp, params, rounds=spec.rounds,
                                eval_every=max(spec.rounds, 1),
                                compute=compute)
    sim_t = np.asarray([l.sim_time for l in logs])
    net_t = np.asarray([l.sim_time_network for l in logs])
    m = {
        "decode_rate": float(np.mean([l.decoded for l in logs])),
        "consumed_mean": float(np.mean([l.consumed for l in logs])),
        "budget": strat.budget,
        "sim_time_mean": float(sim_t.mean()),
        "sim_time_network_mean": float(net_t.mean()),
        "final_train_loss": logs[-1].train_loss,
    }
    if spec.compute_coupled:
        # positive per-client compute offsets must push every round's
        # decode strictly past the network-only clock
        m["compute_dominates"] = bool((sim_t > net_t).all())
        m["compute_overhead_mean"] = float((sim_t - net_t).mean())
    return m


def _run_scenario_events(spec: ScenarioSpec) -> tuple[dict, list]:
    """Execute one scenario under a scenario-local tracer.

    A fresh enabled :class:`repro.obs.Tracer` is installed for the
    duration (and the previous tracer restored after), so every engine
    / sim / serve span the scenario emits is captured; the entry's
    ``per_stage`` field is the per-span-name time breakdown.  Returns
    ``(entry, trace_events)`` — both plain picklable data, which is
    what lets :func:`run_grid` ship them back from spawn workers and
    merge the per-process traces by pid lane.
    """
    prev = obs.get_tracer()
    tr = obs.Tracer(process_name=f"grid:{spec.name}")
    obs.set_tracer(tr)
    try:
        with obs.timed("grid.scenario", cat="grid",
                       scenario=spec.name) as sw:
            if spec.strategy in SIM_STRATEGIES:
                metrics = _sim_metrics(spec)
            elif spec.strategy.startswith(HIER_PREFIX):
                metrics = _hier_metrics(spec)
            elif spec.strategy in ASYNC_STRATEGIES:
                metrics = _async_metrics(spec)
            elif spec.strategy == ENGINE_STRATEGY:
                metrics = _engine_metrics(spec)
            else:
                raise ValueError(f"unknown strategy {spec.strategy!r}")
    finally:
        obs.set_tracer(prev)
    prev.extend(tr.events)       # no-op unless an outer tracer is live
    entry = {
        "seed": spec.seed,
        "axes": spec.axes(),
        "rounds": spec.rounds,
        "clients_per_round": spec.clients_per_round,
        "wall_s": sw.dur_s,
        "per_stage": obs.stage_totals(tr.events,
                                      exclude=_ENVELOPE_SPANS),
        **metrics,
    }
    return entry, tr.events


def run_scenario(spec: ScenarioSpec) -> dict:
    """Execute one scenario; returns its GRID_*.json entry."""
    return _run_scenario_events(spec)[0]


def run_grid(specs: Sequence[ScenarioSpec], jobs: int = 1,
             progress=None, trace_path=None) -> dict:
    """Run every scenario; returns ``{name: entry}`` in spec order.

    ``jobs > 1`` fans out over a spawn-context process pool (each
    worker is a fresh interpreter with its own jax runtime — fork
    would corrupt a warmed-up XLA client).  Results are identical to
    the serial path; only wall time changes.

    ``trace_path`` writes the merged Chrome trace of every scenario to
    that file — workers keep their own pid, so a ``jobs=N`` run shows
    N process lanes on one epoch-aligned timeline.
    """
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError("duplicate scenario names in grid")
    all_events: list = []
    if jobs <= 1 or len(specs) <= 1:
        results = {}
        for s in specs:
            results[s.name], events = _run_scenario_events(s)
            all_events.extend(events)
            if progress:
                progress(f"{s.name}: {results[s.name]['wall_s']:.1f}s")
    else:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        ctx = mp.get_context("spawn")
        results: dict[str, Optional[dict]] = {}
        with ProcessPoolExecutor(max_workers=min(jobs, len(specs)),
                                 mp_context=ctx) as pool:
            futures = {s.name: pool.submit(_run_scenario_events, s)
                       for s in specs}
            for name in names:
                results[name], events = futures[name].result()
                all_events.extend(events)
                if progress:
                    progress(f"{name}: "
                             f"{results[name]['wall_s']:.1f}s")
    if trace_path is not None:
        obs.save_events(obs.merge_events(all_events), trace_path)
    # a live outer tracer also receives the merged events (the serial
    # path already extended it per scenario; workers could not)
    if jobs > 1 and len(specs) > 1:
        obs.get_tracer().extend(all_events)
    return results
