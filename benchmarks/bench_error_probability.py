"""Paper Table I column 2: decode error probability vs (s, η).

Monte-Carlo decode-failure rate of FedNC through η re-coding hops,
against the Prop.-2 bound 1-(1-2^-s)^η and the paper's reported
numbers (0.5 / 0.0625 / 0.0039 / 0.3239)."""
from __future__ import annotations

from repro import obs
from repro.core import security

from .common import emit

SETTINGS = [(1, 1), (4, 1), (8, 1), (8, 100)]
PAPER = {(1, 1): 0.5, (4, 1): 0.0625, (8, 1): 0.0039, (8, 100): 0.3239}


def run(trials: int = 120, K: int = 10) -> None:
    for s, eta in SETTINGS:
        bound = security.error_probability_bound(s, eta)
        with obs.timed("bench.error_prob", cat="bench",
                       s=s, eta=eta) as sw:
            if eta <= 1:
                rate = security.simulate_error_probability(
                    K=K, s=s, eta=eta, trials=trials, seed=0)
            else:
                # η=100 hops: fewer trials, each trial is 100 recodes
                rate = security.simulate_error_probability(
                    K=K, s=s, eta=eta, trials=max(20, trials // 5),
                    seed=0)
        us = sw.dur_s * 1e6
        emit(f"error_prob_s{s}_eta{eta}", us,
             f"sim={rate:.4f};bound={bound:.4f};paper={PAPER[(s, eta)]}")


if __name__ == "__main__":
    run()
