"""Paper §III-A.3 robustness: decode success under packet erasure,
FedNC (K + extra coded tuples) vs FedAvg (each packet irreplaceable)."""
from __future__ import annotations

import jax

from repro import obs
from repro.core import fednc
from repro.core.channel import ErasureChannel
from repro.core.fednc import FedNCConfig

from .common import emit


def run(trials: int = 30) -> None:
    key = jax.random.PRNGKey(0)
    K = 8
    clients = [{"w": jax.random.normal(jax.random.fold_in(key, i),
                                       (256,))} for i in range(K)]
    weights = [1.0 / K] * K
    prev = clients[0]
    for p_erase in (0.0, 0.1, 0.3):
        for extra in (0, 4):
            ok_nc = 0
            ok_avg = 0
            with obs.timed("bench.robustness", cat="bench") as sw:
                for t in range(trials):
                    chan = ErasureChannel(p_erase, seed=t)
                    cfg = FedNCConfig(s=8, extra_tuples=extra)
                    r = fednc.fednc_round(clients, weights, prev, cfg,
                                          jax.random.PRNGKey(t),
                                          channel=chan)
                    ok_nc += int(r.decoded)
                    chan2 = ErasureChannel(p_erase, seed=t)
                    r2 = fednc.fedavg_round(clients, weights, prev,
                                            channel=chan2)
                    # FedAvg "success" = heard from every client
                    ok_avg += int(r2.report.delivered == K)
                sw.fence(getattr(r, "global_params", None))
            us = sw.dur_s * 1e6
            emit(f"robust_p{p_erase}_extra{extra}", us,
                 f"fednc_decode={ok_nc / trials:.2f};"
                 f"fedavg_full={ok_avg / trials:.2f}")


if __name__ == "__main__":
    run()
