"""FedNC-as-collective wire cost: reads the dry-run records and reports
collective bytes per aggregation mode (the §Perf baseline/optimized
comparison).  Skips gracefully when the dry-run JSON is absent."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = "EXPERIMENTS/dryrun_results.json"
PERF = "EXPERIMENTS/perf_results.json"


def run() -> None:
    paths = [p for p in (RESULTS, PERF) if os.path.exists(p)]
    if not paths:
        emit("collective_bytes", 0.0, "skipped=no_dryrun_json")
        return
    seen = set()
    for path in paths:
        with open(path) as f:
            recs = json.load(f)
        for r in recs:
            if r.get("status") != "ok" or r.get("shape") != "train_4k":
                continue
            key = (r["arch"], r["mesh"], r.get("agg_mode"))
            if key in seen:
                continue
            seen.add(key)
            ha = r.get("hlo_analysis", {})
            emit(f"collective_{r['arch']}_{r['mesh']}_{r.get('agg_mode')}",
                 0.0,
                 f"coll_GB={ha.get('collective_bytes_per_device', 0) / 1e9:.1f};"
                 f"bottleneck={r['roofline']['bottleneck']}")


if __name__ == "__main__":
    run()
