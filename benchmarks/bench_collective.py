"""FedNC-as-collective wire cost + the fused hierarchy round benchmark.

Part 1 (`run_hierarchy`): dispatch counts and wall time per
hierarchical round at E ∈ {2, 4, 8} edge servers, fused
`CodingEngine.multi_edge_round` vs the per-edge reference path
(`core.hierarchy.per_edge_round_reference`) — the ROADMAP "fused
multi-edge round cuts dispatch overhead" claim, recorded in
``BENCH_hierarchy.json``.  Both paths consume identical RNG streams,
so they decode the same bytes; only the dispatch structure differs.

Part 2: reads the dry-run records and reports collective bytes per
aggregation mode (the §Perf baseline/optimized comparison).  Skips
gracefully when the dry-run JSON is absent.
"""
from __future__ import annotations

import json
import os
import pathlib

from .common import emit, time_us

RESULTS = "EXPERIMENTS/dryrun_results.json"
PERF = "EXPERIMENTS/perf_results.json"

# hierarchy bench shape: K clients, L symbols/client, streamed chunks
HIER_K = 16
HIER_L = 1 << 16
HIER_CHUNK_L = 1 << 14
HIER_EDGES = (2, 4, 8)
HIER_SPARES = 2


def _hier_round(engine, P, edges, wan_seed: int, fused: bool, cfg=None):
    import jax
    from repro.core.channel import ErasureChannel
    from repro.core.hierarchy import per_edge_round_reference

    chan = ErasureChannel(p_erase=0.1, seed=wan_seed)
    key = jax.random.PRNGKey(wan_seed)
    if fused:
        out = engine.multi_edge_round(
            P, key, [e.client_ids for e in edges],
            spare_per_edge=HIER_SPARES, wan_channel=chan)
    else:
        out = per_edge_round_reference(
            P, edges, cfg, key, spare_per_edge=HIER_SPARES,
            wan_channel=chan)
    if out.packets is not None:
        out.packets.block_until_ready()
    return out


def run_hierarchy(json_path: str = "BENCH_hierarchy.json") -> dict:
    """Fused vs per-edge hierarchical round at E ∈ {2, 4, 8}."""
    import jax
    from repro.core.fednc import FedNCConfig, engine_for
    from repro.core.gf import get_field
    from repro.core.hierarchy import partition_edges

    cfg = FedNCConfig(s=8, kernel_impl="jnp_packed", chunk_l=HIER_CHUNK_L)
    engine = engine_for(cfg)
    f = get_field(cfg.s)
    P = f.random_elements(jax.random.PRNGKey(0), (HIER_K, HIER_L))
    results: dict[str, dict] = {
        "shape": {"K": HIER_K, "L": HIER_L, "chunk_l": HIER_CHUNK_L,
                  "spare_per_edge": HIER_SPARES, "p_erase": 0.1,
                  "kernel": engine.kernel_name},
    }
    for E in HIER_EDGES:
        edges = partition_edges(HIER_K, E)
        row: dict[str, float] = {}
        for fused in (True, False):
            tag = "fused" if fused else "per_edge"
            # dispatch count: diff the engine's monotonic counter over
            # one round (seed held fixed so both paths do decode work)
            before = engine.dispatch_count
            _hier_round(engine, P, edges, 1, fused, cfg)
            row[f"dispatches_{tag}"] = engine.dispatch_count - before
            row[f"us_{tag}"] = time_us(
                lambda f=fused: _hier_round(engine, P, edges, 1, f, cfg),
                warmup=1, iters=3)
        row["dispatch_ratio"] = (row["dispatches_per_edge"] /
                                 max(row["dispatches_fused"], 1))
        row["speedup"] = row["us_per_edge"] / row["us_fused"]
        results[f"hierarchy_E{E}"] = row
        emit(f"hierarchy_round_E{E}_fused", row["us_fused"],
             f"dispatches={row['dispatches_fused']};"
             f"vs_per_edge={row['dispatches_per_edge']};"
             f"speedup={row['speedup']:.2f}x")
    pathlib.Path(json_path).write_text(json.dumps(results, indent=2))
    return results


def run() -> None:
    run_hierarchy()
    paths = [p for p in (RESULTS, PERF) if os.path.exists(p)]
    if not paths:
        emit("collective_bytes", 0.0, "skipped=no_dryrun_json")
        return
    seen = set()
    for path in paths:
        with open(path) as f:
            recs = json.load(f)
        for r in recs:
            if r.get("status") != "ok" or r.get("shape") != "train_4k":
                continue
            key = (r["arch"], r["mesh"], r.get("agg_mode"))
            if key in seen:
                continue
            seen.add(key)
            ha = r.get("hlo_analysis", {})
            emit(f"collective_{r['arch']}_{r['mesh']}_{r.get('agg_mode')}",
                 0.0,
                 f"coll_GB={ha.get('collective_bytes_per_device', 0) / 1e9:.1f};"
                 f"bottleneck={r['roofline']['bottleneck']}")


if __name__ == "__main__":
    run()
