"""Shared benchmark utilities: timing + CSV emission.

Timing goes through ``repro.obs.timed`` (the repo-wide stopwatch) and
every measured call is fenced with ``obs.device_sync`` before the
clock stops — JAX dispatches asynchronously, so an unfenced loop times
the Python dispatch, not the device work.
"""
from __future__ import annotations

from typing import Callable

from repro import obs


def time_us(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    out = None
    for _ in range(warmup):
        out = fn(*args)
    obs.device_sync(out)          # warmup work must not leak into timing
    with obs.timed("bench.time_us", cat="bench", iters=iters) as sw:
        for _ in range(iters):
            out = fn(*args)
        sw.fence(out)
    return sw.dur_s / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
