"""Paper Fig. 4: system scale N=100 -> 200 at fixed K (participation
rate 0.1 -> 0.05).  FedNC's advantage grows as participation drops —
CI-scale reproduction with the synthetic image task."""
from __future__ import annotations


import jax

from repro import obs
from repro.core.channel import BlindBoxChannel
from repro.core.fednc import FedNCConfig
from repro.data import make_image_dataset, mixed_noniid_partition
from repro.federation import (FedAvgStrategy, FedNCStrategy, FLExperiment,
                              LocalTrainer, run_experiment)
from repro.federation.rounds import final_accuracy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn, merge_bn_stats
from repro.optim import adam

from .common import emit


def _run(N: int, scheme: str, *, k=5, rounds=5, seed=0) -> float:
    ds = make_image_dataset(40 * N, seed=0, size=16)
    test = make_image_dataset(200, seed=99, size=16)
    parts = mixed_noniid_partition(ds.labels, N, seed=1)
    chan = BlindBoxChannel(budget=k, seed=seed)
    strat = (FedNCStrategy(config=FedNCConfig(s=8), channel=chan)
             if scheme == "fednc" else FedAvgStrategy(channel=chan))
    trainer = LocalTrainer(
        loss_fn=lambda p, b: cnn_loss(p, b, train=True),
        optimizer=adam(1e-3), local_epochs=1,
        state_merge=merge_bn_stats)
    exp = FLExperiment(trainer=trainer, strategy=strat, partitions=parts,
                       dataset=ds, test_set=test,
                       eval_fn=lambda p, x, y: cnn_accuracy(p, x, y),
                       clients_per_round=k, batch_size=16, seed=seed)
    params = init_cnn(jax.random.PRNGKey(seed), image_size=16)
    logs = run_experiment(exp, params, rounds=rounds,
                          eval_every=max(rounds // 2, 1))
    return final_accuracy(logs, 1)


def run(rounds: int = 5, seeds: tuple = (0, 1)) -> None:
    import numpy as np
    for N in (40, 80):          # scaled-down analogue of 100 -> 200
        accs = {}
        for scheme in ("fedavg", "fednc"):
            with obs.timed("bench.scale", cat="bench") as sw:
                vals = [_run(N, scheme, rounds=rounds, seed=s)
                        for s in seeds]
                accs[scheme] = float(np.mean(vals))
            us = sw.dur_s * 1e6 / len(seeds)
            emit(f"scale_N{N}_{scheme}", us,
                 f"acc={accs[scheme]:.3f};seeds={len(seeds)}")
        emit(f"scale_N{N}_delta", 0.0,
             f"fednc_minus_fedavg={accs['fednc'] - accs['fedavg']:+.3f}")


if __name__ == "__main__":
    run()
