"""Security benchmark: the paper's "Secure" claim, measured.

Four sections, one ``BENCH_security.json``:

* **eavesdrop_edge_sweep** — the structural rank wall (paper
  §III-A.2): an attacker capturing every row of e < E edge links of a
  hierarchical round holds coding vectors supported on < K columns, so
  its basis can never reach rank K.  The sweep records achieved rank
  vs. number of tapped edges; the bar is *zero* full leaks below full
  capture and a guaranteed full leak at e = E.
* **leak_probability** — the probabilistic wall for per-tuple
  interception: each of the n transmitted tuples is captured
  independently with probability p, and the measured full-leak rate
  over Monte-Carlo trials must match the closed form
  ``core.security.eavesdropper_leak_probability`` (a binomial mixture
  of full-rank probabilities) within a 5-sigma binomial tolerance.
  Colluding-client entries reuse the same closed form with K-c
  unknowns: c colluders quotient their own packets out of the space.
  Every trial with fewer than K independent rows is also asserted to
  not leak (``rank_wall_violations`` must stay 0).
* **byzantine_detection** — active corruption at rate b per tuple
  (``adversary.ByzantineChannel``, mode "both") against the engine's
  redundant-rank cross-check (``round(verify=True)``): corrupted
  rounds must be flagged (detection_rate >= 0.99 at the full tier), an
  accepted-but-wrong decode (``undetected_bad_decodes``) must never
  happen, and ``rounds_to_recovery`` prices the retry loop.
* **replay_detection** — the seeded wire format's own attack: re-sent
  4-byte headers with forged payloads arrive as dependent rows whose
  payloads contradict the basis, so ``StreamDecoder(detect=True)``
  must flag every single one.

``scripts/check_bench.py`` enforces the bars; ``--smoke`` writes
``BENCH_security_smoke.json`` (``config.smoke`` true) with the
full-tier-only bars relaxed, mirroring ``bench_serve``.

    PYTHONPATH=src python -m benchmarks.bench_security [--smoke]
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib

import jax
import numpy as np

from repro.adversary import (ByzantineChannel, EavesdropperView,
                             replayed_seed_batch, rounds_to_recovery,
                             tap_edges)
from repro.core.security import eavesdropper_leak_probability
from repro.engine import CodingEngine, EngineConfig, StreamDecoder

from .common import emit

K = 8            # generation size for the flat (engine) sections
L = 64           # payload symbols per packet
S = 8
N_TUPLES = 12    # transmitted coded tuples per round (K + redundancy)
EDGES = 4        # hierarchy width for the edge sweep
EDGE_CLIENTS = 4     # clients per edge (hierarchy K = EDGES * this)
SPARE_PER_EDGE = 1
LEAK_PS = (0.5, 0.7, 0.9)
COLLUDERS = 3
BYZ_RATES = (0.02, 0.05, 0.1)
SEED = 13

FULL = {"edge_trials": 20, "leak_trials": 600, "byz_rounds": 24,
        "replays": 12}
SMOKE = {"edge_trials": 6, "leak_trials": 120, "byz_rounds": 6,
         "replays": 6}


def _edge_sweep(engine: CodingEngine, trials: int) -> dict:
    """Achieved rank vs. number of tapped edge links."""
    k = EDGES * EDGE_CLIENTS
    edges = [tuple(range(e * EDGE_CLIENTS, (e + 1) * EDGE_CLIENTS))
             for e in range(EDGES)]
    n_out = [len(ids) + SPARE_PER_EDGE for ids in edges]
    entries = []
    for tapped in range(EDGES + 1):
        ranks, leaks = [], 0
        for t in range(trials):
            key = jax.random.PRNGKey(SEED * 1000 + t)
            A = engine.multi_edge_coding_matrix(key, edges, k, n_out)
            view = EavesdropperView(K=k, s=S, seed=t)
            view.observe(tap_edges(A, edges, range(tapped),
                                   spare_per_edge=SPARE_PER_EDGE))
            ranks.append(view.rank)
            leaks += int(view.full_leak)
        entries.append({
            "tapped_edges": tapped,
            "rank_mean": float(np.mean(ranks)),
            "rank_max": int(np.max(ranks)),
            "full_leak_rate": leaks / trials,
        })
        emit(f"security_edge_tap{tapped}of{EDGES}", 0.0,
             f"rank_mean={entries[-1]['rank_mean']:.2f};"
             f"leak_rate={entries[-1]['full_leak_rate']:.2f}")
    return {"edges": EDGES, "K": k, "spare_per_edge": SPARE_PER_EDGE,
            "trials": trials, "entries": entries}


def _leak_point(engine: CodingEngine, p: float, colluders: int,
                trials: int) -> dict:
    """Measured full-leak rate vs. the closed form at one (p, c)."""
    leaks = violations = 0
    cids = tuple(range(colluders))
    for t in range(trials):
        key = jax.random.PRNGKey(SEED * 7000 + t)
        A = engine.coding_matrix(key, N_TUPLES, K)
        view = EavesdropperView(K=K, s=S, seed=t, p_intercept=p,
                                colluders=cids)
        view.intercept(A)
        leaks += int(view.full_leak)
        if view.intercepted + colluders < K and view.full_leak:
            violations += 1    # impossible: < K rows spanned K dims
    measured = leaks / trials
    closed = eavesdropper_leak_probability(N_TUPLES, K - colluders,
                                           p, s=S)
    tol = 5.0 * math.sqrt(max(closed * (1 - closed), 1e-12) / trials)
    entry = {
        "n": N_TUPLES, "K": K, "colluders": colluders,
        "p_intercept": p, "trials": trials, "measured": measured,
        "closed_form": closed, "abs_err": abs(measured - closed),
        "tol": tol, "rank_wall_violations": violations,
    }
    emit(f"security_leak_p{p:g}_c{colluders}", 0.0,
         f"measured={measured:.4f};closed={closed:.4f};tol={tol:.4f}")
    return entry


def _byzantine_point(engine: CodingEngine, rate: float,
                     rounds: int) -> dict:
    """Detection + recovery stats for one corruption rate."""
    P = jax.random.randint(jax.random.PRNGKey(SEED), (K, L), 0, 256,
                           dtype=jax.numpy.uint8)
    channel = ByzantineChannel(rate, seed=SEED, mode="both")
    corrupted = flagged = rank_failures = undetected = accepted = 0
    for r in range(rounds):
        before = channel.corrupted
        out = engine.round(P, jax.random.fold_in(
            jax.random.PRNGKey(SEED + 1), r), channel, verify=True)
        hit = channel.corrupted > before
        corrupted += int(hit)
        if not out.ok:
            rank_failures += 1
        elif out.verified is False:
            flagged += 1
        else:
            accepted += 1
            if hit and not bool((out.packets == P).all()):
                undetected += 1
    detected = flagged + rank_failures
    recovery = rounds_to_recovery(
        engine, P, jax.random.PRNGKey(SEED + 2), channel)
    entry = {
        "rate": rate, "rounds": rounds,
        "corrupted_rounds": corrupted, "detected": detected,
        "detection_rate": (detected / corrupted if corrupted else 1.0),
        "flagged": flagged, "rank_failures": rank_failures,
        "accepted": accepted, "undetected_bad_decodes": undetected,
        "recovery": recovery,
    }
    emit(f"security_byzantine_b{rate:g}", 0.0,
         f"corrupted={corrupted}/{rounds};"
         f"detection={entry['detection_rate']:.2f};"
         f"recovery_rounds={recovery['rounds']}")
    return entry


def _replay(engine_seeded: CodingEngine, replays: int) -> dict:
    """Every replayed 4-byte header must be flagged by the decoder."""
    P = jax.random.randint(jax.random.PRNGKey(SEED), (K, L), 0, 256,
                           dtype=jax.numpy.uint8)
    seeds = engine_seeded.coding_seeds(jax.random.PRNGKey(SEED + 3),
                                       N_TUPLES)
    batch = engine_seeded.encode_seeded(P, seeds)
    attacked = replayed_seed_batch(batch, replays, s=S, seed=SEED)
    dec = StreamDecoder(K=K, L=L, s=S, detect=True)
    dec.ingest(attacked.seeds, attacked.C)
    entry = {
        "replays": replays, "flagged": dec.inconsistent,
        "first_inconsistent_at": dec.first_inconsistent_at,
        "decoded": bool(dec.complete),
    }
    emit("security_replay", 0.0,
         f"replays={replays};flagged={dec.inconsistent}")
    return entry


def run(fast: bool = False, smoke: bool = False,
        json_path: str = "BENCH_security.json") -> dict:
    knobs = SMOKE if smoke else dict(
        FULL, leak_trials=300 if fast else FULL["leak_trials"],
        byz_rounds=12 if fast else FULL["byz_rounds"])
    engine = CodingEngine(EngineConfig(
        s=S, kernel="jnp_packed", extra_tuples=N_TUPLES - K))
    engine_seeded = CodingEngine(EngineConfig(
        s=S, kernel="jnp_packed_seeded", extra_tuples=N_TUPLES - K))

    leak_entries = [_leak_point(engine, p, 0, knobs["leak_trials"])
                    for p in LEAK_PS]
    leak_entries.append(_leak_point(engine, 0.5, COLLUDERS,
                                    knobs["leak_trials"]))

    results = {
        "config": {
            "K": K, "L": L, "s": S, "n_tuples": N_TUPLES,
            "seed": SEED, "smoke": bool(smoke), **knobs,
        },
        "eavesdrop_edge_sweep": _edge_sweep(engine,
                                            knobs["edge_trials"]),
        "leak_probability": {"trials": knobs["leak_trials"],
                             "entries": leak_entries},
        "byzantine_detection": {
            "rounds": knobs["byz_rounds"], "mode": "both",
            "entries": [_byzantine_point(engine, b, knobs["byz_rounds"])
                        for b in BYZ_RATES],
        },
        "replay_detection": _replay(engine_seeded, knobs["replays"]),
    }
    pathlib.Path(json_path).write_text(json.dumps(results, indent=2))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trial counts, full-tier bars relaxed")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    path = args.json or ("BENCH_security_smoke.json" if args.smoke
                         else "BENCH_security.json")
    print("name,us_per_call,derived")
    run(fast=args.fast, smoke=args.smoke, json_path=path)


if __name__ == "__main__":
    main()
