"""Decode-server benchmark: continuous batching vs per-job dispatch.

One Poisson multi-tenant trace (mixed seeded + materialized wire
formats) is replayed through the same `DecodeServer` twice: once with
the bank advancing every slot in ONE vmapped dispatch per scheduler
tick (``batched``), once with the identical kernel dispatched per job
(``sequential``) — the only difference between the modes is dispatch
granularity, so the throughput gap IS the continuous-batching win.

Writes ``BENCH_serve.json``:

* ``config`` — trace + server shape (``smoke: true`` relaxes the bar
  for the CI smoke artifact).
* ``serve_batched`` / ``serve_sequential`` — packets/s, p50/p99 job
  completion latency, ticks, dispatches, max concurrent jobs (best of
  ``reps`` replays, after a warm-up replay to absorb jit compiles).
* ``batched_vs_sequential`` — ``x`` = throughput ratio at
  ``concurrent_jobs`` jobs in flight.  Bar (scripts/check_bench.py):
  x ≥ 1.5 with ≥ 8 concurrent jobs.
* ``payloads_match`` — both modes decoded byte-identical payloads at
  identical completion arrival counts (checked every replay).
* ``metrics`` — the batched server's ``fednc-metrics-v1`` snapshot
  (queue-depth gauge, ingest-batch and job-latency histograms).

``--trace [PATH]`` additionally replays the batched mode once under an
enabled tracer and writes the Chrome trace (default
``TRACE_serve.json``; summarize with ``python -m repro.obs``).

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--trace]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro import obs
from repro.serve import poisson_multitenant_trace, serve_trace

from .common import emit

JOBS = 24        # tenant rounds in the trace (3 waves over 8 slots)
K = 16           # generation size per round
L = 256          # payload symbols per packet
S = 8
SLOTS = 8        # concurrent jobs in the decoder bank
G_TICK = 8       # packets per job per tick
EXTRA = 6        # redundant tuples per round
TRACE_SEED = 11

SMOKE = {"jobs": 10, "K": 8, "L": 64, "slots": 8, "g_tick": 4,
         "extra": 3, "reps": 1}


def _serve_stats(trace, *, slots, g_tick, batched, reps):
    """Best-of-`reps` replay (server state is rebuilt each time)."""
    best, sig = None, None
    for _ in range(reps):
        rep = serve_trace(trace, slots=slots, g_tick=g_tick,
                          batched=batched)
        if best is None or rep.wall_s < best.wall_s:
            best = rep
        s = [(c.job, c.arrivals, c.payload_sha) for c in rep.completions]
        assert sig is None or sig == s, "replay drifted across reps"
        sig = s
    p50, p99 = best.latency_percentiles()
    entry = {
        "mode": "batched" if batched else "sequential",
        "jobs": best.jobs, "completed": best.completed,
        "packets": best.packets_ingested,
        "late_dropped": best.late_dropped,
        "ticks": best.ticks, "dispatches": best.dispatches,
        "max_concurrent": best.max_concurrent,
        "wall_s": best.wall_s, "packets_per_s": best.packets_per_s,
        "p50_latency_s": p50, "p99_latency_s": p99,
    }
    return entry, sig, best.metrics


def run(fast: bool = False, smoke: bool = False,
        json_path: str = "BENCH_serve.json",
        trace_path: str | None = None) -> dict:
    if smoke:
        jobs, k, l = SMOKE["jobs"], SMOKE["K"], SMOKE["L"]
        slots, g_tick = SMOKE["slots"], SMOKE["g_tick"]
        extra, reps = SMOKE["extra"], SMOKE["reps"]
    else:
        jobs, k, l, slots, g_tick, extra = (JOBS, K, L, SLOTS, G_TICK,
                                            EXTRA)
        reps = 2 if fast else 4
    trace = poisson_multitenant_trace(
        jobs, k, l, s=S, rate=4.0, extra_packets=extra,
        seeded="mixed", duplicate_rate=0.05, seed=TRACE_SEED)

    # warm-up replays compile the (slots, g_tick) batched program and
    # the per-slot sequential program before anything is timed
    serve_trace(trace, slots=slots, g_tick=g_tick, batched=True)
    serve_trace(trace, slots=slots, g_tick=g_tick, batched=False)

    bat, sig_b, bat_metrics = _serve_stats(
        trace, slots=slots, g_tick=g_tick, batched=True, reps=reps)
    seq, sig_s, _ = _serve_stats(
        trace, slots=slots, g_tick=g_tick, batched=False, reps=reps)

    x = bat["packets_per_s"] / seq["packets_per_s"]
    results = {
        "config": {
            "jobs": jobs, "K": k, "L": l, "s": S, "slots": slots,
            "g_tick": g_tick, "extra_packets": extra,
            "duplicate_rate": 0.05, "trace_seed": TRACE_SEED,
            "packets": trace.n_packets,
            "wire_bytes": trace.wire_bytes(),
            "reps": reps, "smoke": bool(smoke),
        },
        "serve_batched": bat,
        "serve_sequential": seq,
        "batched_vs_sequential": {
            "x": x, "concurrent_jobs": bat["max_concurrent"],
        },
        "payloads_match": sig_b == sig_s,
        "metrics": bat_metrics,
    }

    if trace_path:
        # one extra traced batched replay — the timed replays above ran
        # with tracing off, so the published numbers are untraced
        tr = obs.set_tracer(obs.Tracer(process_name="bench_serve"))
        try:
            serve_trace(trace, slots=slots, g_tick=g_tick,
                        batched=True)
        finally:
            obs.set_tracer(obs.NULL_TRACER)
        obs.save_events(tr.events, trace_path)
        emit("serve_trace_events", 0.0,
             f"events={len(tr.events)};path={trace_path}")

    for entry in (bat, seq):
        emit(f"serve_{entry['mode']}", entry["wall_s"] * 1e6,
             f"pkts_per_s={entry['packets_per_s']:.0f};"
             f"p50={entry['p50_latency_s'] * 1e3:.1f}ms;"
             f"p99={entry['p99_latency_s'] * 1e3:.1f}ms;"
             f"ticks={entry['ticks']};"
             f"dispatches={entry['dispatches']}")
    emit("serve_batched_vs_sequential", 0.0,
         f"x={x:.2f};concurrent={bat['max_concurrent']};"
         f"match={results['payloads_match']}")

    pathlib.Path(json_path).write_text(json.dumps(results, indent=2))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, bar relaxed (CI smoke artifact)")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--trace", nargs="?", const="TRACE_serve.json",
                    default=None, metavar="PATH",
                    help="write a Chrome trace of one batched replay")
    args = ap.parse_args()
    path = args.json or ("BENCH_serve_smoke.json" if args.smoke
                         else "BENCH_serve.json")
    print("name,us_per_call,derived")
    run(fast=args.fast, smoke=args.smoke, json_path=path,
        trace_path=args.trace)


if __name__ == "__main__":
    main()
