"""The full scenario grid: every strategy family x network regime.

Four sections, one ``GRID_grid.json`` (+ ``GRID_grid.md`` summary):

* **scenarios** — the cartesian core: {FedNC stream, FedAvg blind-box}
  x four straggler profiles x populations 10^3/10^4, the stages
  decoder at 10^4, a 10%-dropout cell (FedAvg blocked, FedNC decoding
  survivors), the Section-III hierarchy at E in {2, 4, 8} over both
  the table-oracle and lane-packed GF kernels, the async FL
  strategies, and the adversary axis (eavesdrop / collude / byzantine
  engine cells + an edge-link tap on the hierarchy).  Per-scenario
  seeds come from ``repro.grid.spec`` and never change as the grid
  grows.
* **delay_sweep** — the ROADMAP's delay-reordered regime: per-client
  latency offsets reorder arrivals, breaking the blind-box i.i.d.
  assumption Prop. 1 prices at K·H(K).  The sweep publishes measured
  FedAvg draw counts *above* K·H(K) as a function of reorder spread
  (the bar: > 1.2x at the widest spread), while FedNC's rank law is
  arrival-order-invariant.
* **compute_coupling** — the async round with per-client local-
  training compute folded into the arrival clock: the coupled decode
  time must strictly dominate the network-only schedule of the same
  seed, every round (the bar: ``dominates`` is true).

``scripts/check_bench.py`` validates the artifact's schema and both
bars; ``python -m repro.grid --smoke`` is the CI-sized sibling.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.grid import (GridAxes, grid_document, markdown_report,
                        run_grid)

from .common import emit

DELAY_SPREADS = (0.0, 1.0, 2.0, 5.0, 10.0)
DELAY_INFLATION_BAR = 1.2      # measured ~2.0x at spread 10
K = 32
JOBS = 2


def _axes_list(rounds: int, fast: bool) -> list[GridAxes]:
    """The grid, as a list of axis blocks (one expand each)."""
    pops = (10**3,) if fast else (10**3, 10**4)
    stragglers = (("exponential", "pareto") if fast else
                  ("constant", "exponential", "lognormal", "pareto"))
    blocks = [
        # the Prop.-1 core: both collectors, every straggler tail
        GridAxes(strategy=("fednc_stream", "fedavg"),
                 straggler=stragglers, population=pops,
                 clients_per_round=K, rounds=rounds),
        # the geometric-stage decoder (huge-cohort path) cross-checks
        # the StreamDecoder's measured rank law
        GridAxes(strategy=("fednc_stages",),
                 straggler=("lognormal",), population=(10**4,),
                 clients_per_round=K, rounds=rounds),
        # dropout: FedAvg blocks on its missing coupon, FedNC decodes
        # the survivors (draw-ratio fields are null here by design)
        GridAxes(strategy=("fednc_stream",), straggler=("lognormal",),
                 p_dropout=(0.1,), population=(10**4,),
                 clients_per_round=K, rounds=rounds),
        # the §III hierarchy across the GF kernel axis
        GridAxes(strategy=("hier:2", "hier:4", "hier:8"),
                 kernel=("jnp",) if fast else ("jnp", "jnp_packed"),
                 clients_per_round=16, rounds=2 if fast else 3),
        # async FL end to end, network-only and compute-coupled
        GridAxes(strategy=("async", "async_compute"),
                 straggler=("lognormal",), clients_per_round=4,
                 rounds=2 if fast else 4),
        # the adversary axis: passive interception / collusion /
        # byzantine corruption against the flat engine round, plus the
        # edge-link tap against the §III hierarchy (BENCH_security.json
        # carries the closed-form validation; these cells put the same
        # models on the grid's coordinates)
        GridAxes(strategy=("engine",),
                 kernel=("jnp_packed",) if fast
                 else ("jnp_packed", "jnp_packed_seeded"),
                 adversary=("eavesdrop:0.6", "collude:4",
                            "byzantine:0.05"),
                 clients_per_round=16, rounds=2 if fast else 4),
        GridAxes(strategy=("hier:4",), kernel=("jnp_packed",),
                 adversary=("eavesdrop:0.6",),
                 clients_per_round=16, rounds=2 if fast else 3),
    ]
    return blocks


def _delay_sweep(rounds: int) -> dict:
    """FedAvg inflation beyond K·H(K) vs per-client reorder spread."""
    from repro.core import coupon
    axes = GridAxes(strategy=("fedavg",), straggler=("exponential",),
                    delay_spread=DELAY_SPREADS, population=(10**4,),
                    clients_per_round=K, rounds=rounds, base_seed=3)
    specs = axes.expand()
    results = list(run_grid(specs, jobs=JOBS).values())
    kh_k = coupon.expected_draws_fedavg(K)
    sweep = {
        "clients_per_round": K,
        "rounds": rounds,
        "kh_k": kh_k,
        "spreads": [s.delay_spread for s in specs],
        "fedavg_draws_mean": [r["fedavg_draws_mean"] for r in results],
        "fednc_draws_mean": [r["fednc_draws_mean"] for r in results],
        "draw_ratio": [r["draw_ratio"] for r in results],
        "inflation": [r["fedavg_inflation"] for r in results],
    }
    sweep["max_inflation"] = float(np.max(sweep["inflation"]))
    sweep["inflation_bar"] = DELAY_INFLATION_BAR
    sweep["exceeds_bar"] = bool(
        sweep["inflation"][-1] > DELAY_INFLATION_BAR)
    for d, infl in zip(sweep["spreads"], sweep["inflation"],
                       strict=True):
        emit(f"grid_delay_spread{d:g}", 0.0,
             f"fedavg_inflation={infl:.3f}x_of_KHK")
    return sweep


def run(rounds: int = 60, fast: bool = False,
        json_path: str = "GRID_grid.json",
        md_path: str = "GRID_grid.md") -> dict:
    if fast:
        rounds = min(rounds, 20)

    scenarios: dict[str, dict] = {}
    blocks = _axes_list(rounds, fast)
    # the recorded config is the union of every block's axis values
    config = blocks[0].config()
    for axes in blocks[1:]:
        for k, vals in axes.config()["axes"].items():
            merged = config["axes"][k] + [
                v for v in vals if v not in config["axes"][k]]
            config["axes"][k] = merged
    for axes in blocks:
        block = run_grid(axes.expand(), jobs=JOBS)
        for name, entry in block.items():
            scenarios[name] = entry
            emit(f"grid_{name}", entry["wall_s"] * 1e6,
                 f"strategy={entry['axes']['strategy']};"
                 f"draw_ratio={entry.get('draw_ratio')};"
                 f"decode={entry.get('decode_rate', entry.get('fednc_decode_rate'))}")

    sweep = _delay_sweep(rounds)

    cc_name = next(n for n, e in scenarios.items()
                   if e["axes"]["strategy"] == "async_compute")
    cc = scenarios[cc_name]
    compute_coupling = {
        "scenario": cc_name,
        "rounds": cc["rounds"],
        "sim_time_mean": cc["sim_time_mean"],
        "sim_time_network_mean": cc["sim_time_network_mean"],
        "overhead_mean": cc["compute_overhead_mean"],
        "dominates": cc["compute_dominates"],
    }
    emit("grid_compute_coupling", 0.0,
         f"coupled={cc['sim_time_mean']:.3f};"
         f"network={cc['sim_time_network_mean']:.3f};"
         f"dominates={cc['compute_dominates']}")

    doc = grid_document(config, scenarios, full=True,
                        delay_sweep=sweep,
                        compute_coupling=compute_coupling)
    pathlib.Path(json_path).write_text(json.dumps(doc, indent=2))
    pathlib.Path(md_path).write_text(markdown_report(doc))
    return doc


if __name__ == "__main__":
    run()
