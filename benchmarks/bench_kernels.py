"""GF coding kernel micro-benchmarks: unpacked vs lane-packed, chunked.

Compares the three interpret-free formulations of C = A·P through the
engine registry, each oracle-checked against the table-based jnp
reference before timing:

  * ``jnp``        — table lookup (log/exp gathers)
  * ``jnp_clmul``  — the unpacked Pallas kernel's carry-less-multiply
                     math in pure jnp (one symbol per int32 lane)
  * ``jnp_packed`` — the lane-packed kernel's ladder in pure jnp
                     (4 symbols per int32 lane), run through the
                     engine's chunked streaming executor
  * ``jnp_packed_seeded`` — the same ladder with coefficients
                     regenerated from 4-byte row seeds inside the
                     matmul (no (n, K) operand), oracle-checked
                     against the expanded materialized product

Seeded wire-overhead rows quantify the K+L -> 4+L header shrink at
K in {32, 128, 512} (``seeded_wire_overhead_K*``), and
``seeded_vs_materialized_L*`` records the throughput ratio of the
seeded ladder against its materialized sibling at matched shapes —
both gated by ``scripts/check_bench.py``.

On this CPU container the Pallas kernels run in interpret mode (a
correctness harness, not a speed claim), so the packed-vs-unpacked
throughput claim is measured on the jnp formulations — identical math,
identical chunking, no interpreter overhead.  On TPU the same registry
names resolve to the compiled kernels.

Besides the CSV rows, writes ``BENCH_kernels.json`` (cwd) with
bytes/s + symbols/s per (kernel, L) and the packed:unpacked speedup,
so the perf trajectory is machine-readable from this PR onward.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.core.gf import get_field
from repro.core.packets import packet_wire_bytes
from repro.core.seeds import draw_seeds, expand_rows
from repro.engine import CodingEngine, EngineConfig, is_seeded_kernel
from repro.kernels import ref

from .common import emit, time_us

# lane lengths (symbols): 64 KiB, 1 MiB, 4 MiB packets at s=8
LANE_SWEEP = (1 << 16, 1 << 20, 1 << 22)
CHUNK_L = 1 << 18
K = 10
S = 8
WIRE_KS = (32, 128, 512)     # generation sizes for wire-overhead rows
WIRE_L = 1 << 18             # payload symbols for wire-overhead rows

KERNELS = ("jnp", "jnp_clmul", "jnp_packed", "jnp_packed_seeded")


def _bench_one(kernel: str, s: int, K: int, L: int) -> dict:
    f = get_field(s)
    key = jax.random.PRNGKey(0)
    if is_seeded_kernel(kernel):
        rows = draw_seeds(key, K)
        A = expand_rows(rows, K, s)     # the oracle's materialized view
    else:
        rows = A = f.random_elements(key, (K, K))
    P = f.random_elements(jax.random.fold_in(key, 1), (K, L))
    eng = CodingEngine(EngineConfig(s=s, kernel=kernel, chunk_l=CHUNK_L))
    # oracle check before timing: exact field math, any mismatch is a bug
    got = eng.matmul(rows, P)
    want = ref.gf_matmul_ref(A, P, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    us = time_us(lambda: eng.matmul(rows, P).block_until_ready(), iters=3)
    sym = K * L
    return {
        "us_per_call": us,
        "symbols_per_s": sym / (us / 1e6),
        "bytes_per_s": sym * s / 8 / (us / 1e6),   # s bits per symbol
        "s": s, "K": K, "L": L,
        "chunk_l": CHUNK_L,
    }


def run(json_path: str = "BENCH_kernels.json") -> dict:
    results: dict[str, dict] = {}
    for L in LANE_SWEEP:
        for kernel in KERNELS:
            r = _bench_one(kernel, S, K, L)
            name = f"gf_encode_{kernel}_s{S}_K{K}_L{L}"
            results[name] = r
            emit(name, r["us_per_call"],
                 f"{r['symbols_per_s'] / 1e6:.0f}Msym/s;"
                 f"chunk={CHUNK_L};round_bytes={K * L}")
        speedup = (results[f"gf_encode_jnp_packed_s{S}_K{K}_L{L}"]
                   ["symbols_per_s"] /
                   results[f"gf_encode_jnp_clmul_s{S}_K{K}_L{L}"]
                   ["symbols_per_s"])
        results[f"packed_vs_unpacked_speedup_L{L}"] = {"x": speedup}
        emit(f"packed_vs_unpacked_L{L}", 0.0, f"{speedup:.2f}x")
        ratio = (results[f"gf_encode_jnp_packed_seeded_s{S}_K{K}_L{L}"]
                 ["symbols_per_s"] /
                 results[f"gf_encode_jnp_packed_s{S}_K{K}_L{L}"]
                 ["symbols_per_s"])
        results[f"seeded_vs_materialized_L{L}"] = {"x": ratio}
        emit(f"seeded_vs_materialized_L{L}", 0.0, f"{ratio:.2f}x")
    # wire economics: header bytes per packet drop from K·s/8 to 4
    for Kw in WIRE_KS:
        mat = packet_wire_bytes(Kw, WIRE_L, S, seeded=False)
        sed = packet_wire_bytes(Kw, WIRE_L, S, seeded=True)
        results[f"seeded_wire_overhead_K{Kw}"] = {
            "K": Kw, "L": WIRE_L, "s": S,
            "materialized_bytes": mat, "seeded_bytes": sed,
            "ratio": sed / mat,
        }
        emit(f"seeded_wire_overhead_K{Kw}", 0.0,
             f"{sed}B vs {mat}B ({sed / mat:.4f}x)")
    # small-field sanity row (s=4, the paper's other field size)
    r4 = _bench_one("jnp_packed", 4, 16, 1 << 18)
    results["gf_encode_jnp_packed_s4_K16_L262144"] = r4
    emit("gf_encode_jnp_packed_s4_K16_L262144", r4["us_per_call"],
         f"{r4['symbols_per_s'] / 1e6:.0f}Msym/s")
    pathlib.Path(json_path).write_text(json.dumps(results, indent=2))
    return results


if __name__ == "__main__":
    run()
