"""GF coding kernel micro-benchmarks: jnp oracle vs Pallas (interpret).

On this CPU container the Pallas kernel runs in interpret mode (a
correctness harness, not a speed claim) — the derived column reports
symbol throughput of the jnp path, which IS the production CPU path,
plus the paper-relevant encode cost per FL round."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gf import get_field
from repro.kernels import ops

from .common import emit, time_us


def run() -> None:
    key = jax.random.PRNGKey(0)
    for s, K, L in [(8, 10, 1 << 16), (8, 10, 1 << 20), (1, 10, 1 << 20),
                    (4, 16, 1 << 18)]:
        f = get_field(s)
        A = f.random_elements(key, (K, K))
        P = f.random_elements(jax.random.fold_in(key, 1), (K, L))

        jitted = jax.jit(lambda a, p: ops.gf_matmul(a, p, s=s, impl="jnp"))
        jitted(A, P).block_until_ready()
        us = time_us(lambda: jitted(A, P).block_until_ready(), iters=3)
        mbps = (K * L) / (us / 1e6) / 1e6
        emit(f"gf_encode_jnp_s{s}_K{K}_L{L}", us,
             f"{mbps:.0f}Msym/s;round_bytes={K * L}")


if __name__ == "__main__":
    run()
