"""Paper Fig. 3 / Table I column 3: FedAvg vs FedNC test accuracy under
iid and mixed non-iid splits with blind-box reception.

CI-scale: 16x16 synthetic images, small CNN rounds — direction of the
effects (FedNC ≈ FedAvg iid; FedNC > FedAvg non-iid) is what the paper
claims; examples/paper_experiments.py runs the larger version."""
from __future__ import annotations


import jax
import numpy as np

from repro import obs
from repro.core.channel import BlindBoxChannel
from repro.core.fednc import FedNCConfig
from repro.data import (iid_partition, make_image_dataset,
                        mixed_noniid_partition)
from repro.federation import (FedAvgStrategy, FedNCStrategy, FLExperiment,
                              LocalTrainer, run_experiment)
from repro.federation.rounds import final_accuracy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn, merge_bn_stats
from repro.optim import adam

from .common import emit


def _run(split: str, scheme: str, *, n=600, clients=20, k=5, rounds=6,
         seed=0) -> float:
    ds = make_image_dataset(n, seed=0, size=16)
    test = make_image_dataset(200, seed=99, size=16)
    if split == "iid":
        parts = iid_partition(ds.labels, clients, seed=1)
    else:
        parts = mixed_noniid_partition(ds.labels, clients, seed=1)
    if scheme == "fednc":
        strat = FedNCStrategy(config=FedNCConfig(s=8),
                              channel=BlindBoxChannel(budget=k, seed=seed))
    else:
        strat = FedAvgStrategy(channel=BlindBoxChannel(budget=k, seed=seed))
    trainer = LocalTrainer(
        loss_fn=lambda p, b: cnn_loss(p, b, train=True),
        optimizer=adam(1e-3), local_epochs=2,
        state_merge=merge_bn_stats)
    exp = FLExperiment(trainer=trainer, strategy=strat, partitions=parts,
                       dataset=ds, test_set=test,
                       eval_fn=lambda p, x, y: cnn_accuracy(p, x, y),
                       clients_per_round=k, batch_size=16, seed=seed)
    params = init_cnn(jax.random.PRNGKey(seed), image_size=16)
    logs = run_experiment(exp, params, rounds=rounds,
                          eval_every=max(rounds // 2, 1))
    return final_accuracy(logs, 1)


def run(rounds: int = 6, seeds: tuple = (0, 1, 2)) -> None:
    for split in ("iid", "noniid"):
        accs = {}
        for scheme in ("fedavg", "fednc"):
            with obs.timed("bench.fl_accuracy", cat="bench") as sw:
                vals = [_run(split, scheme, rounds=rounds, seed=s)
                        for s in seeds]
                accs[scheme] = float(np.mean(vals))
            us = sw.dur_s * 1e6 / len(seeds)
            emit(f"fl_acc_{split}_{scheme}", us,
                 f"acc={accs[scheme]:.3f};rounds={rounds};"
                 f"seeds={len(seeds)}")
        emit(f"fl_acc_{split}_delta", 0.0,
             f"fednc_minus_fedavg={accs['fednc'] - accs['fedavg']:+.3f}")


if __name__ == "__main__":
    run()
