"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows.

  bench_error_probability  Table I col 2 (p_e vs s, η; Prop. 2 bound)
  bench_coupon             Prop. 1 (blind-box E[G]: K·H(K) vs ~K)
  bench_robustness         §III-A.3 (erasure tolerance)
  bench_kernels            GF coding kernel throughput
  bench_fl_accuracy        Fig. 3 / Table I col 3 (iid + non-iid)
  bench_scale              Fig. 4 (N=100→200 analogue)
  bench_collective         fused hierarchy round (BENCH_hierarchy.json)
                           + mesh FedNC wire cost (from dry-run records)
  bench_sim                event-driven network sim: time-to-rank-K vs
                           time-to-all-K, populations 10^3..10^6
                           (BENCH_sim.json)
  bench_grid               the scenario grid: strategy x straggler x
                           delay-reorder x dropout x population x GF
                           kernel, + the delay-reordered FedAvg sweep
                           and compute-coupled arrivals (GRID_grid.json)
  bench_serve              multi-tenant decode server: continuous
                           batching vs per-job dispatch, packets/s +
                           p50/p99 job latency (BENCH_serve.json)
  bench_security           the adversary models vs the closed forms:
                           edge-tap rank wall, eavesdropper leak
                           probability, byzantine detection + replay
                           flagging (BENCH_security.json)

See benchmarks/README.md for every suite and JSON field.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduce Monte-Carlo trials / FL rounds")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_collective, bench_coupon,
                   bench_error_probability, bench_fl_accuracy,
                   bench_grid, bench_kernels, bench_robustness,
                   bench_scale, bench_security, bench_serve,
                   bench_sim)

    suites = [
        ("error_probability",
         lambda: bench_error_probability.run(trials=40 if args.fast
                                             else 120)),
        ("coupon", lambda: bench_coupon.run(trials=80 if args.fast
                                            else 200)),
        ("robustness", lambda: bench_robustness.run(
            trials=10 if args.fast else 30)),
        ("kernels", bench_kernels.run),
        ("fl_accuracy", lambda: bench_fl_accuracy.run(
            rounds=3 if args.fast else 10)),
        ("scale", lambda: bench_scale.run(rounds=3 if args.fast else 5)),
        ("collective", bench_collective.run),
        ("sim", lambda: bench_sim.run(rounds=40 if args.fast else 100)),
        ("grid", lambda: bench_grid.run(fast=args.fast)),
        ("serve", lambda: bench_serve.run(fast=args.fast)),
        ("security", lambda: bench_security.run(fast=args.fast)),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        try:
            fn()
            import jax
            jax.clear_caches()   # bound the CPU-client compile cache
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
