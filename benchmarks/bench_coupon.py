"""Paper Prop. 1: blind-box draws E[G] — FedAvg K·H(K) vs FedNC ~K."""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.core import coupon

from .common import emit


def run(trials: int = 200) -> None:
    for K in (10, 20, 50):
        with obs.timed("bench.coupon", cat="bench", K=K) as sw:
            sim = float(np.mean(coupon.simulate_fedavg_draws(K, trials)))
        us = sw.dur_s * 1e6
        exact = coupon.expected_draws_fedavg(K)
        asym = coupon.expected_draws_fedavg_asymptotic(K)
        nc = coupon.expected_draws_fednc(K, s=8)
        emit(f"coupon_K{K}", us,
             f"fedavg_sim={sim:.1f};fedavg_KHK={exact:.1f};"
             f"paper_eq5={asym:.1f};fednc={nc:.2f};"
             f"speedup={exact / nc:.2f}x")


if __name__ == "__main__":
    run()
