"""Network-simulator benchmark: time-to-rank-K vs time-to-all-K.

Sweeps population sizes 10^3..10^6 under two straggler gap
distributions (lognormal σ=1 and pareto α=1.5, both unit mean) with a
64-client cohort per round, running FedNC (StreamDecoder, stops at
rank K) and FedAvg (blind-box collector, waits for every cohort
member) against the *same* arrival stream.

Writes ``BENCH_sim.json``:

* ``sim_pop{N}_{dist}`` — per-scenario means: simulated
  time-to-decode for both collectors, measured draw counts, and the
  measured/predicted draw ratio (prediction = Prop. 1 via
  `core.coupon`).  The bar, enforced by ``scripts/check_bench.py``:
  every scenario's ``draw_ratio_rel_err`` ≤ 0.10.
* ``dropout_p10`` — robustness accounting at 10% mid-round dropout:
  FedNC decodes the survivors every round, FedAvg completes only when
  nobody dropped.
* ``scale_1e6`` — the wall-clock of a 10^6-client, 100-round
  simulation on CPU (bar: < 60 s).
"""
from __future__ import annotations

import json
import pathlib

from repro import obs
from repro.core import coupon
from repro.sim import (STRAGGLER_PROFILES, NetworkSimulator,
                       PopulationConfig, SimConfig)

from .common import emit

POPULATIONS = (10**3, 10**4, 10**5, 10**6)
STRAGGLERS = ("lognormal", "pareto")
K = 64
S = 8


def _run_scenario(pop: int, straggler: str, rounds: int, seed: int,
                  **pop_kw) -> tuple[dict, float]:
    cfg = SimConfig(
        population=PopulationConfig(n_clients=pop, **pop_kw),
        clients_per_round=K, s=S,
        gap=STRAGGLER_PROFILES[straggler], seed=seed)
    with obs.timed("bench.sim", cat="bench", pop=pop) as sw:
        trace = NetworkSimulator(cfg).run(rounds)
    return trace.summary(), sw.dur_s


def run(rounds: int = 100, json_path: str = "BENCH_sim.json") -> dict:
    predicted = (coupon.expected_draws_fedavg(K)
                 / coupon.expected_draws_fednc(K, S))
    results: dict[str, dict] = {
        "config": {
            "clients_per_round": K, "s": S, "rounds": rounds,
            "populations": list(POPULATIONS),
            "stragglers": list(STRAGGLERS),
            "predicted_draw_ratio": predicted,
        },
    }

    for straggler in STRAGGLERS:
        for i, pop in enumerate(POPULATIONS):
            summary, wall = _run_scenario(pop, straggler, rounds,
                                          seed=1000 + i)
            ratio = summary["draw_ratio"]
            rel_err = abs(ratio - predicted) / predicted
            entry = {
                "population": pop, "straggler": straggler,
                "rounds": rounds,
                "time_to_rank_k_mean": summary["time_to_rank_k_mean"],
                "time_to_all_k_mean": summary["time_to_all_k_mean"],
                "time_to_rank_k_p50": summary["time_to_rank_k_p50"],
                "time_to_all_k_p50": summary["time_to_all_k_p50"],
                "time_speedup": summary["time_speedup"],
                "fednc_draws_mean": summary["fednc_draws_mean"],
                "fedavg_draws_mean": summary["fedavg_draws_mean"],
                "draw_ratio": ratio,
                "predicted_draw_ratio": predicted,
                "draw_ratio_rel_err": rel_err,
                "wall_s": wall,
            }
            results[f"sim_pop{pop}_{straggler}"] = entry
            emit(f"sim_pop{pop}_{straggler}", wall * 1e6,
                 f"t_rankK={entry['time_to_rank_k_mean']:.3f};"
                 f"t_allK={entry['time_to_all_k_mean']:.3f};"
                 f"draw_ratio={ratio:.3f};pred={predicted:.3f};"
                 f"rel_err={rel_err:.3%}")

    # robustness accounting: 10% of selected participants drop
    # mid-round and never transmit
    drop_summary, _ = _run_scenario(10**4, "lognormal", rounds,
                                    seed=77, p_dropout=0.1)
    results["dropout_p10"] = {
        "population": 10**4, "p_dropout": 0.1, "rounds": rounds,
        "fednc_decode_rate": drop_summary["fednc_decode_rate"],
        "fedavg_complete_rate": drop_summary["fedavg_complete_rate"],
        "n_dropped_mean": drop_summary["n_dropped_mean"],
    }
    emit("sim_dropout_p10", 0.0,
         f"fednc_rate={drop_summary['fednc_decode_rate']:.2f};"
         f"fedavg_rate={drop_summary['fedavg_complete_rate']:.2f}")

    # the scale bar: 10^6 clients x 100 rounds on CPU in < 60 s.  The
    # sweep above already ran that exact workload when rounds >= 100;
    # only shorter (--fast) sweeps need a dedicated run.
    if rounds >= 100:
        scale_rounds = rounds
        scale_wall = results["sim_pop1000000_pareto"]["wall_s"]
    else:
        scale_rounds = 100
        _, scale_wall = _run_scenario(10**6, "pareto", scale_rounds,
                                      seed=5)
    results["scale_1e6"] = {
        "population": 10**6, "rounds": scale_rounds,
        "wall_s": scale_wall, "under_60s": bool(scale_wall < 60.0),
    }
    emit("sim_scale_1e6", scale_wall * 1e6,
         f"rounds={scale_rounds};wall_s={scale_wall:.2f};"
         f"under_60s={scale_wall < 60.0}")

    pathlib.Path(json_path).write_text(json.dumps(results, indent=2))
    return results


if __name__ == "__main__":
    run()
